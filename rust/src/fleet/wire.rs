//! Length-prefixed binary wire protocol between the fleet master and its
//! workers (no external serialization deps — hand-rolled little-endian
//! codec, versioned and bounds-checked).
//!
//! Frame layout on the wire:
//!
//! ```text
//! ┌────────────┬─────────┬─────┬────────────────┐
//! │ len: u32le │ ver: u8 │ tag │ payload        │
//! └────────────┴─────────┴─────┴────────────────┘
//!       len = 2 + payload length (covers ver + tag + payload)
//! ```
//!
//! Integers are little-endian; `f64`/`f32` travel as their IEEE-754 bit
//! patterns. A reader rejects frames whose version byte is not
//! [`WIRE_VERSION`], whose length exceeds [`MAX_FRAME_LEN`], or whose
//! payload is truncated or over-long for the tag — a malformed peer can
//! never make the master allocate unboundedly or mis-parse.
//!
//! Version 2 adds the gradient data plane: tensor-bearing frames
//! ([`Frame::JobSpec`], [`Frame::Partition`], [`Frame::Params`],
//! [`Frame::GradAssign`], [`Frame::GradResult`]) whose float payloads
//! are chunked so no single frame exceeds [`MAX_FRAME_LEN`], plus the
//! [`Frame::Error`] reply a master sends before closing an incompatible
//! (v1) or misbehaving connection.
//!
//! The serving control plane (`sgc serve --listen-jobs`) speaks the
//! same protocol on a separate listener: a client sends one
//! [`Frame::Submit`] and receives exactly one [`Frame::Accepted`] or
//! [`Frame::Rejected`] (or an [`Frame::Error`] farewell when the frame
//! is malformed). All strings are length-bounded on decode, so a
//! hostile client can neither over-allocate nor wedge the reactor.

use std::io::{self, Read, Write};

/// Protocol version; bump on any incompatible frame change. Version 2
/// introduced the gradient data-plane frames (tags 6–11).
pub const WIRE_VERSION: u8 = 2;

/// Upper bound on one frame's `len` field (1 MiB): an `Assign` for a
/// full-replication task at n = 4096 chunks is still < 20 KiB, and
/// tensor payloads are chunked at [`DATA_FLOATS_PER_FRAME`] floats.
pub const MAX_FRAME_LEN: u32 = 1 << 20;

/// Tensor floats carried per data-plane frame (256 KiB of payload —
/// comfortably under [`MAX_FRAME_LEN`] with headers).
pub const DATA_FLOATS_PER_FRAME: usize = 1 << 16;

/// Hard cap on a reassembled tensor's declared `total` float count
/// (64 MiB): a lying length prefix can never force the receiver to
/// allocate beyond this.
pub const MAX_TENSOR_FLOATS: u32 = 1 << 24;

/// Longest [`Frame::Error`] message accepted on decode.
pub const MAX_ERROR_MSG: usize = 1024;

/// Longest job name accepted in a [`Frame::Submit`] (decode rejects
/// longer, so a hostile client can never make the admission queue
/// buffer unbounded names).
pub const MAX_JOB_NAME: usize = 64;

/// Longest scheme-spec string accepted in a [`Frame::Submit`].
pub const MAX_SUBMIT_SPEC: usize = 256;

/// Everything that can go wrong decoding a frame.
#[derive(Debug)]
pub enum WireError {
    /// Underlying stream error.
    Io(io::Error),
    /// Peer closed the connection cleanly at a frame boundary.
    Closed,
    /// Version byte mismatch.
    BadVersion(u8),
    /// Unknown frame tag.
    BadTag(u8),
    /// Payload shorter than its tag requires.
    Truncated,
    /// Payload longer than its tag requires.
    TrailingBytes,
    /// Declared length outside `[2, MAX_FRAME_LEN]`.
    BadLength(u32),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io error: {e}"),
            WireError::Closed => write!(f, "connection closed"),
            WireError::BadVersion(v) => {
                write!(f, "wire version {v} (expected {WIRE_VERSION})")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::Truncated => write!(f, "truncated frame payload"),
            WireError::TrailingBytes => write!(f, "trailing bytes after frame payload"),
            WireError::BadLength(l) => write!(f, "bad frame length {l}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// A protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker → master on connect: claim a worker slot.
    Hello { worker_id: u32 },
    /// Master → worker: execute one round's task. `work_units` is the
    /// normalized load (what the latency of the task scales with);
    /// `chunks` are the data-chunk ids the task covers (the synthetic
    /// minitask folds them into its checksum; a real workload would load
    /// them).
    Assign { round: u32, work_units: f64, chunks: Vec<u32> },
    /// Worker → master: one round's result. `compute_s` is the worker's
    /// own execution-time measurement (diagnostic only — the master
    /// trusts its wall-clock arrival observation, never the worker's
    /// clock); `checksum` proves the minitask ran.
    Result { worker_id: u32, round: u32, compute_s: f64, checksum: u64 },
    /// Worker → master: liveness signal between results.
    Heartbeat { worker_id: u32, round: u32 },
    /// Master → worker: exit the serve loop.
    Shutdown,
    /// Master → worker: the connection is being refused or torn down
    /// deliberately (`code` = [`ERR_BAD_VERSION`] etc.) with a short
    /// human-readable reason. Sent before close so an incompatible peer
    /// sees a clear rejection instead of a silent hangup.
    Error {
        /// Machine-readable reason (`ERR_*` constants).
        code: u8,
        /// Human-readable detail (≤ [`MAX_ERROR_MSG`] bytes on decode).
        msg: String,
    },
    /// Master → worker: dimensions of a real-gradient job's model. Sent
    /// once per `(job, connection)` before any [`Frame::Partition`].
    JobSpec {
        /// Scheduler job id.
        job: u32,
        /// Input feature width.
        input: u32,
        /// Output class count.
        classes: u32,
        /// First hidden-layer width.
        hidden1: u32,
        /// Second hidden-layer width.
        hidden2: u32,
    },
    /// Master → worker: one slice of a data partition. The full tensor
    /// for a chunk is `x ‖ y ‖ w` flattened (`rows·input + rows·classes
    /// + rows` floats); `off`/`total` are float offsets into it and
    /// slices carry ≤ [`DATA_FLOATS_PER_FRAME`] floats each.
    Partition {
        /// Scheduler job id.
        job: u32,
        /// Chunk id within the job's sharding.
        chunk: u32,
        /// Sample rows in the chunk (padding rows carry weight 0).
        rows: u32,
        /// Float offset of `data` within the full tensor.
        off: u32,
        /// Total float count of the full tensor.
        total: u32,
        /// This slice's floats.
        data: Vec<f32>,
    },
    /// Master → worker: one slice of a job's flattened parameter vector
    /// (same `off`/`total` chunking as [`Frame::Partition`]).
    Params {
        /// Scheduler job id.
        job: u32,
        /// Monotonic parameter version (bumped per optimizer step).
        version: u32,
        /// Float offset of `data` within the flat parameter vector.
        off: u32,
        /// Total float count of the flat parameter vector.
        total: u32,
        /// This slice's floats.
        data: Vec<f32>,
    },
    /// Master → worker: execute one round's real-gradient task — run
    /// forward/backward over each unit's chunks and return the encoded
    /// partial gradient as [`Frame::GradResult`] slices.
    GradAssign {
        /// Scheduler job id.
        job: u32,
        /// Cluster round (the master's submission sequence number).
        round: u32,
        /// Parameter version the gradients must be computed against.
        param_version: u32,
        /// Normalized load (drives the synthetic latency padding).
        work_units: f64,
        /// The work units, with encoding coefficients resolved by the
        /// master (workers never need the code plan).
        units: Vec<GradUnit>,
    },
    /// Worker → master: one slice of a round's encoded gradient payload
    /// (concatenated per-unit gradient vectors, in unit order).
    GradResult {
        /// Sender's worker id.
        worker_id: u32,
        /// Scheduler job id.
        job: u32,
        /// Cluster round being answered.
        round: u32,
        /// Parameter version the gradient was computed against (stale
        /// versions are dropped by the master).
        param_version: u32,
        /// Worker-measured compute seconds (diagnostic only).
        compute_s: f64,
        /// Float offset of `data` within the full payload.
        off: u32,
        /// Total float count of the full payload.
        total: u32,
        /// This slice's floats.
        data: Vec<f32>,
    },
    /// Client → master: ask the serving loop to admit one job. Answered
    /// with exactly one [`Frame::Accepted`] or [`Frame::Rejected`] (or a
    /// [`Frame::Error`] farewell when the frame itself is malformed).
    Submit {
        /// Client-chosen job name (≤ [`MAX_JOB_NAME`] bytes on decode;
        /// duplicates among queued/active jobs are rejected).
        name: String,
        /// Scheme spec string, e.g. `gc:2` (≤ [`MAX_SUBMIT_SPEC`] bytes
        /// on decode; parsed master-side against the fleet width).
        scheme: String,
        /// Session jobs (paper iterations) the job runs.
        session_jobs: u32,
        /// Admission priority: higher activates first; preemption evicts
        /// the lowest first.
        priority: u8,
    },
    /// Master → client: the submission was admitted into the queue.
    Accepted {
        /// Scheduler job id assigned to the submission.
        job: u32,
        /// Queue depth (queued, not yet active) right after admission.
        queue_depth: u32,
    },
    /// Master → client: the submission was load-shed.
    Rejected {
        /// Why (`queue full`, `duplicate job name …`, `scheme … exceeds
        /// fleet capacity`, …; ≤ [`MAX_ERROR_MSG`] bytes on decode).
        reason: String,
    },
}

/// One work unit inside a [`Frame::GradAssign`]: which chunk gradients
/// to compute and how to combine them. The master resolves encoding
/// coefficients from its code plan before serializing, so workers apply
/// plain weighted sums without knowing `(n, s)` or the `B` matrix.
#[derive(Clone, Debug, PartialEq)]
pub enum GradUnit {
    /// Return chunk `chunk`'s gradient for paper-job `job` unscaled.
    Plain {
        /// Paper-job (iteration) index the gradient serves.
        job: u32,
        /// Chunk id to differentiate over.
        chunk: u32,
    },
    /// Return `Σ coeff·g_chunk` over `terms` for paper-job `job`.
    Coded {
        /// Paper-job (iteration) index the combination serves.
        job: u32,
        /// `(chunk, coefficient)` terms of the linear combination.
        terms: Vec<(u32, f64)>,
    },
}

/// [`Frame::Error`] code: the peer spoke an unsupported wire version.
pub const ERR_BAD_VERSION: u8 = 1;
/// [`Frame::Error`] code: the handshake frame was not a valid `Hello`.
pub const ERR_BAD_HANDSHAKE: u8 = 2;

const TAG_HELLO: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_RESULT: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_ERROR: u8 = 6;
const TAG_JOB_SPEC: u8 = 7;
const TAG_PARTITION: u8 = 8;
const TAG_PARAMS: u8 = 9;
const TAG_GRAD_ASSIGN: u8 = 10;
const TAG_GRAD_RESULT: u8 = 11;
const TAG_SUBMIT: u8 = 12;
const TAG_ACCEPTED: u8 = 13;
const TAG_REJECTED: u8 = 14;

const UNIT_PLAIN: u8 = 1;
const UNIT_CODED: u8 = 2;

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => TAG_HELLO,
            Frame::Assign { .. } => TAG_ASSIGN,
            Frame::Result { .. } => TAG_RESULT,
            Frame::Heartbeat { .. } => TAG_HEARTBEAT,
            Frame::Shutdown => TAG_SHUTDOWN,
            Frame::Error { .. } => TAG_ERROR,
            Frame::JobSpec { .. } => TAG_JOB_SPEC,
            Frame::Partition { .. } => TAG_PARTITION,
            Frame::Params { .. } => TAG_PARAMS,
            Frame::GradAssign { .. } => TAG_GRAD_ASSIGN,
            Frame::GradResult { .. } => TAG_GRAD_RESULT,
            Frame::Submit { .. } => TAG_SUBMIT,
            Frame::Accepted { .. } => TAG_ACCEPTED,
            Frame::Rejected { .. } => TAG_REJECTED,
        }
    }

    /// Encode to the on-wire byte sequence (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        match self {
            Frame::Hello { worker_id } => put_u32(&mut payload, *worker_id),
            Frame::Assign { round, work_units, chunks } => {
                put_u32(&mut payload, *round);
                put_f64(&mut payload, *work_units);
                put_u32(&mut payload, chunks.len() as u32);
                for &c in chunks {
                    put_u32(&mut payload, c);
                }
            }
            Frame::Result { worker_id, round, compute_s, checksum } => {
                put_u32(&mut payload, *worker_id);
                put_u32(&mut payload, *round);
                put_f64(&mut payload, *compute_s);
                put_u64(&mut payload, *checksum);
            }
            Frame::Heartbeat { worker_id, round } => {
                put_u32(&mut payload, *worker_id);
                put_u32(&mut payload, *round);
            }
            Frame::Shutdown => {}
            Frame::Error { code, msg } => {
                payload.push(*code);
                let bytes = msg.as_bytes();
                let take = bytes.len().min(MAX_ERROR_MSG);
                put_u32(&mut payload, take as u32);
                payload.extend_from_slice(&bytes[..take]);
            }
            Frame::JobSpec { job, input, classes, hidden1, hidden2 } => {
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *input);
                put_u32(&mut payload, *classes);
                put_u32(&mut payload, *hidden1);
                put_u32(&mut payload, *hidden2);
            }
            Frame::Partition { job, chunk, rows, off, total, data } => {
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *chunk);
                put_u32(&mut payload, *rows);
                put_u32(&mut payload, *off);
                put_u32(&mut payload, *total);
                put_f32s(&mut payload, data);
            }
            Frame::Params { job, version, off, total, data } => {
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *version);
                put_u32(&mut payload, *off);
                put_u32(&mut payload, *total);
                put_f32s(&mut payload, data);
            }
            Frame::GradAssign { job, round, param_version, work_units, units } => {
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *round);
                put_u32(&mut payload, *param_version);
                put_f64(&mut payload, *work_units);
                put_u32(&mut payload, units.len() as u32);
                for u in units {
                    match u {
                        GradUnit::Plain { job, chunk } => {
                            payload.push(UNIT_PLAIN);
                            put_u32(&mut payload, *job);
                            put_u32(&mut payload, *chunk);
                        }
                        GradUnit::Coded { job, terms } => {
                            payload.push(UNIT_CODED);
                            put_u32(&mut payload, *job);
                            put_u32(&mut payload, terms.len() as u32);
                            for (c, coeff) in terms {
                                put_u32(&mut payload, *c);
                                put_f64(&mut payload, *coeff);
                            }
                        }
                    }
                }
            }
            Frame::GradResult {
                worker_id,
                job,
                round,
                param_version,
                compute_s,
                off,
                total,
                data,
            } => {
                put_u32(&mut payload, *worker_id);
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *round);
                put_u32(&mut payload, *param_version);
                put_f64(&mut payload, *compute_s);
                put_u32(&mut payload, *off);
                put_u32(&mut payload, *total);
                put_f32s(&mut payload, data);
            }
            Frame::Submit { name, scheme, session_jobs, priority } => {
                put_str(&mut payload, name, MAX_JOB_NAME);
                put_str(&mut payload, scheme, MAX_SUBMIT_SPEC);
                put_u32(&mut payload, *session_jobs);
                payload.push(*priority);
            }
            Frame::Accepted { job, queue_depth } => {
                put_u32(&mut payload, *job);
                put_u32(&mut payload, *queue_depth);
            }
            Frame::Rejected { reason } => put_str(&mut payload, reason, MAX_ERROR_MSG),
        }
        let len = (payload.len() + 2) as u32;
        let mut out = Vec::with_capacity(4 + len as usize);
        put_u32(&mut out, len);
        out.push(WIRE_VERSION);
        out.push(self.tag());
        out.extend_from_slice(&payload);
        out
    }

    /// Decode one frame from its full on-wire bytes (length prefix
    /// included). The inverse of [`encode`](Self::encode).
    pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
        let mut cur = Cursor { buf: bytes, pos: 0 };
        let len = cur.u32()?;
        if len < 2 || len > MAX_FRAME_LEN {
            return Err(WireError::BadLength(len));
        }
        if bytes.len() != 4 + len as usize {
            return Err(if bytes.len() < 4 + len as usize {
                WireError::Truncated
            } else {
                WireError::TrailingBytes
            });
        }
        Self::decode_body(&bytes[4..])
    }

    /// Decode the body (version + tag + payload, no length prefix).
    fn decode_body(body: &[u8]) -> Result<Frame, WireError> {
        let mut cur = Cursor { buf: body, pos: 0 };
        let version = cur.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = cur.u8()?;
        let frame = match tag {
            TAG_HELLO => Frame::Hello { worker_id: cur.u32()? },
            TAG_ASSIGN => {
                let round = cur.u32()?;
                let work_units = cur.f64()?;
                let count = cur.u32()? as usize;
                // a chunk id is 4 bytes; reject counts the payload cannot hold
                if count > cur.remaining() / 4 {
                    return Err(WireError::Truncated);
                }
                let chunks = (0..count).map(|_| cur.u32()).collect::<Result<_, _>>()?;
                Frame::Assign { round, work_units, chunks }
            }
            TAG_RESULT => Frame::Result {
                worker_id: cur.u32()?,
                round: cur.u32()?,
                compute_s: cur.f64()?,
                checksum: cur.u64()?,
            },
            TAG_HEARTBEAT => Frame::Heartbeat { worker_id: cur.u32()?, round: cur.u32()? },
            TAG_SHUTDOWN => Frame::Shutdown,
            TAG_ERROR => {
                let code = cur.u8()?;
                let len = cur.u32()? as usize;
                if len > MAX_ERROR_MSG || len > cur.remaining() {
                    return Err(WireError::Truncated);
                }
                let msg = String::from_utf8_lossy(cur.take(len)?).into_owned();
                Frame::Error { code, msg }
            }
            TAG_JOB_SPEC => Frame::JobSpec {
                job: cur.u32()?,
                input: cur.u32()?,
                classes: cur.u32()?,
                hidden1: cur.u32()?,
                hidden2: cur.u32()?,
            },
            TAG_PARTITION => {
                let job = cur.u32()?;
                let chunk = cur.u32()?;
                let rows = cur.u32()?;
                let (off, total) = cur.slice_header()?;
                let data = cur.f32s()?;
                check_slice(off, &data, total)?;
                Frame::Partition { job, chunk, rows, off, total, data }
            }
            TAG_PARAMS => {
                let job = cur.u32()?;
                let version = cur.u32()?;
                let (off, total) = cur.slice_header()?;
                let data = cur.f32s()?;
                check_slice(off, &data, total)?;
                Frame::Params { job, version, off, total, data }
            }
            TAG_GRAD_ASSIGN => {
                let job = cur.u32()?;
                let round = cur.u32()?;
                let param_version = cur.u32()?;
                let work_units = cur.f64()?;
                let count = cur.u32()? as usize;
                // a unit is at least 9 bytes (kind + job + chunk/count);
                // reject counts the payload cannot hold
                if count > cur.remaining() / 9 {
                    return Err(WireError::Truncated);
                }
                let mut units = Vec::with_capacity(count);
                for _ in 0..count {
                    units.push(match cur.u8()? {
                        UNIT_PLAIN => GradUnit::Plain { job: cur.u32()?, chunk: cur.u32()? },
                        UNIT_CODED => {
                            let job = cur.u32()?;
                            let terms = cur.u32()? as usize;
                            // a term is 12 bytes (chunk + coeff)
                            if terms > cur.remaining() / 12 {
                                return Err(WireError::Truncated);
                            }
                            let terms = (0..terms)
                                .map(|_| Ok((cur.u32()?, cur.f64()?)))
                                .collect::<Result<_, WireError>>()?;
                            GradUnit::Coded { job, terms }
                        }
                        t => return Err(WireError::BadTag(t)),
                    });
                }
                Frame::GradAssign { job, round, param_version, work_units, units }
            }
            TAG_GRAD_RESULT => {
                let worker_id = cur.u32()?;
                let job = cur.u32()?;
                let round = cur.u32()?;
                let param_version = cur.u32()?;
                let compute_s = cur.f64()?;
                let (off, total) = cur.slice_header()?;
                let data = cur.f32s()?;
                check_slice(off, &data, total)?;
                Frame::GradResult {
                    worker_id,
                    job,
                    round,
                    param_version,
                    compute_s,
                    off,
                    total,
                    data,
                }
            }
            TAG_SUBMIT => {
                let name = cur.str(MAX_JOB_NAME)?;
                let scheme = cur.str(MAX_SUBMIT_SPEC)?;
                let session_jobs = cur.u32()?;
                let priority = cur.u8()?;
                Frame::Submit { name, scheme, session_jobs, priority }
            }
            TAG_ACCEPTED => Frame::Accepted { job: cur.u32()?, queue_depth: cur.u32()? },
            TAG_REJECTED => Frame::Rejected { reason: cur.str(MAX_ERROR_MSG)? },
            t => return Err(WireError::BadTag(t)),
        };
        if cur.remaining() != 0 {
            return Err(WireError::TrailingBytes);
        }
        Ok(frame)
    }
}

/// A tensor slice must land inside its declared `total`.
fn check_slice(off: u32, data: &[f32], total: u32) -> Result<(), WireError> {
    if off as usize + data.len() > total as usize {
        return Err(WireError::TrailingBytes);
    }
    Ok(())
}

/// Split a tensor into `(off, slice)` pieces of at most
/// [`DATA_FLOATS_PER_FRAME`] floats for framing (an empty tensor yields
/// one empty slice so the receiver still sees a frame).
pub fn tensor_slices(data: &[f32]) -> Vec<(u32, &[f32])> {
    if data.is_empty() {
        return vec![(0, data)];
    }
    data.chunks(DATA_FLOATS_PER_FRAME)
        .enumerate()
        .map(|(i, c)| ((i * DATA_FLOATS_PER_FRAME) as u32, c))
        .collect()
}

/// Reassembles a tensor from in-order `(off, slice)` pieces (the
/// receive side of [`tensor_slices`]). The declared `total` was already
/// capped at [`MAX_TENSOR_FLOATS`] by frame decoding, so construction
/// never over-allocates. Out-of-order or overlapping slices are
/// rejected (`accept` returns `Err`) — TCP delivers our frames in
/// order, so any other arrival pattern means a confused or hostile
/// peer.
#[derive(Debug)]
pub struct TensorAssembly {
    total: usize,
    data: Vec<f32>,
}

impl TensorAssembly {
    /// Empty assembly expecting `total` floats.
    pub fn new(total: u32) -> Self {
        let total = total.min(MAX_TENSOR_FLOATS) as usize;
        TensorAssembly { total, data: Vec::with_capacity(total) }
    }

    /// Add the next slice; `Ok(true)` once the tensor is complete.
    pub fn accept(&mut self, off: u32, slice: &[f32]) -> Result<bool, WireError> {
        if off as usize != self.data.len() || self.data.len() + slice.len() > self.total {
            return Err(WireError::TrailingBytes);
        }
        self.data.extend_from_slice(slice);
        Ok(self.data.len() == self.total)
    }

    /// The reassembled floats (call once `accept` returned `Ok(true)`).
    pub fn take(self) -> Vec<f32> {
        self.data
    }
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    w.write_all(&frame.encode())?;
    w.flush()
}

/// Read one frame from a stream. Returns [`WireError::Closed`] if the
/// peer closed the connection at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut len_buf = [0u8; 4];
    // distinguish clean EOF (0 bytes) from mid-frame truncation
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) => {
                return Err(if filled == 0 { WireError::Closed } else { WireError::Truncated })
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len < 2 || len > MAX_FRAME_LEN {
        return Err(WireError::BadLength(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    })?;
    Frame::decode_body(&body)
}

/// Incremental frame assembler for non-blocking readers.
///
/// A readiness-driven master reads whatever bytes the socket has —
/// which may be half a frame, or three frames and the length prefix of
/// a fourth. `FrameBuffer` accumulates those bytes ([`feed`](Self::feed))
/// and hands back complete frames one at a time
/// ([`next_frame`](Self::next_frame)), leaving any trailing partial
/// frame buffered for the next readiness event. The same bounds checks
/// as [`read_frame`] apply: a declared length outside
/// `[2, MAX_FRAME_LEN]` is rejected before any payload is buffered
/// past it, so a malformed peer cannot force unbounded buffering.
#[derive(Debug, Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before `start` belong to frames already
    /// returned (compacted away on the next `feed`).
    start: usize,
}

impl FrameBuffer {
    /// Empty buffer.
    pub fn new() -> Self {
        FrameBuffer { buf: Vec::new(), start: 0 }
    }

    /// Append raw bytes read off the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.start > 0 {
            // compact the consumed prefix before growing
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete frame, if the buffer holds one.
    ///
    /// `Ok(None)` means "need more bytes"; an `Err` is fatal for the
    /// connection (the stream can no longer be framed).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = &self.buf[self.start..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(avail[..4].try_into().unwrap());
        if len < 2 || len > MAX_FRAME_LEN {
            return Err(WireError::BadLength(len));
        }
        let total = 4 + len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        let frame = Frame::decode_body(&avail[4..total])?;
        self.start += total;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(Some(frame))
    }

    /// Bytes buffered but not yet returned as frames.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.start
    }

    /// No partial frame is buffered.
    pub fn is_empty(&self) -> bool {
        self.pending_bytes() == 0
    }
}

// --- little-endian primitives -----------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

/// Length-prefixed byte string, truncated at `cap` on encode (decode
/// rejects anything longer via [`Cursor::str`]).
fn put_str(out: &mut Vec<u8>, s: &str, cap: usize) {
    let bytes = s.as_bytes();
    let mut take = bytes.len().min(cap);
    // never split a UTF-8 sequence: back off to a char boundary
    while take > 0 && !s.is_char_boundary(take) {
        take -= 1;
    }
    put_u32(out, take as u32);
    out.extend_from_slice(&bytes[..take]);
}

/// Length-prefixed f32 slice (count then bit patterns).
fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    debug_assert!(
        xs.len() <= DATA_FLOATS_PER_FRAME,
        "tensor slices must be chunked at DATA_FLOATS_PER_FRAME"
    );
    put_u32(out, xs.len() as u32);
    for &x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, k: usize) -> Result<&[u8], WireError> {
        if self.remaining() < k {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + k];
        self.pos += k;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// The `off`/`total` header of a tensor slice, with the
    /// lying-length-prefix guards: `total` capped at
    /// [`MAX_TENSOR_FLOATS`] and `off` inside it.
    fn slice_header(&mut self) -> Result<(u32, u32), WireError> {
        let off = self.u32()?;
        let total = self.u32()?;
        if total > MAX_TENSOR_FLOATS {
            return Err(WireError::BadLength(total));
        }
        if off > total {
            return Err(WireError::Truncated);
        }
        Ok((off, total))
    }

    /// Length-prefixed byte string bounded at `cap`: a hostile length
    /// prefix is rejected before any allocation.
    fn str(&mut self, cap: usize) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        if len > cap || len > self.remaining() {
            return Err(WireError::Truncated);
        }
        Ok(String::from_utf8_lossy(self.take(len)?).into_owned())
    }

    /// Length-prefixed f32 slice; the count must fit the remaining
    /// payload (4 bytes per float), so a hostile count never allocates.
    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let count = self.u32()? as usize;
        if count > self.remaining() / 4 {
            return Err(WireError::Truncated);
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(f32::from_bits(self.u32()?));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { worker_id: 7 },
            Frame::Assign { round: 3, work_units: 0.125, chunks: vec![0, 5, 255] },
            Frame::Assign { round: 1, work_units: 0.0, chunks: vec![] },
            Frame::Result { worker_id: 2, round: 3, compute_s: 0.0421, checksum: 0xdead_beef },
            Frame::Heartbeat { worker_id: 9, round: 12 },
            Frame::Shutdown,
            Frame::Error { code: ERR_BAD_VERSION, msg: "wire version 1".into() },
            Frame::JobSpec { job: 0, input: 64, classes: 10, hidden1: 64, hidden2: 32 },
            Frame::Partition {
                job: 1,
                chunk: 3,
                rows: 2,
                off: 4,
                total: 150,
                data: vec![1.0, -0.5, 3.25, 1e-20],
            },
            Frame::Params { job: 1, version: 9, off: 0, total: 3, data: vec![0.1, 0.2, 0.3] },
            Frame::GradAssign {
                job: 2,
                round: 11,
                param_version: 9,
                work_units: 0.5,
                units: vec![
                    GradUnit::Plain { job: 4, chunk: 1 },
                    GradUnit::Coded { job: 5, terms: vec![(0, 1.0), (3, -0.25)] },
                ],
            },
            Frame::GradResult {
                worker_id: 3,
                job: 2,
                round: 11,
                param_version: 9,
                compute_s: 0.004,
                off: 0,
                total: 2,
                data: vec![-1.0, 2.5],
            },
            Frame::Submit {
                name: "train-a".into(),
                scheme: "m-sgc:1,2,4".into(),
                session_jobs: 24,
                priority: 7,
            },
            Frame::Submit { name: String::new(), scheme: String::new(), session_jobs: 0, priority: 0 },
            Frame::Accepted { job: 3, queue_depth: 2 },
            Frame::Rejected { reason: "queue full (max 3)".into() },
            Frame::Rejected { reason: String::new() },
        ]
    }

    #[test]
    fn round_trips_every_frame() {
        for f in all_frames() {
            let bytes = f.encode();
            assert_eq!(Frame::decode(&bytes).unwrap(), f, "frame {f:?}");
        }
    }

    #[test]
    fn stream_round_trips_back_to_back() {
        let frames = all_frames();
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        assert!(matches!(read_frame(&mut r), Err(WireError::Closed)));
    }

    #[test]
    fn rejects_bad_version() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[4] = WIRE_VERSION + 1;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadVersion(_))));
    }

    #[test]
    fn rejects_unknown_tag() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[5] = 0xff;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadTag(0xff))));
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes = Frame::Hello { worker_id: 1 }.encode();
        assert!(matches!(Frame::decode(&bytes[..bytes.len() - 1]), Err(WireError::Truncated)));
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(Frame::decode(&long), Err(WireError::TrailingBytes)));
        // trailing bytes inside the declared payload are also rejected
        let mut padded = Frame::Shutdown.encode();
        padded[0] += 1; // declared length grows by one…
        padded.push(0); // …and the byte exists, but Shutdown has no payload
        assert!(matches!(Frame::decode(&padded), Err(WireError::TrailingBytes)));
    }

    #[test]
    fn rejects_oversize_length() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[..4].copy_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadLength(_))));
        let mut r = &bytes[..];
        assert!(matches!(read_frame(&mut r), Err(WireError::BadLength(_))));
    }

    #[test]
    fn rejects_chunk_count_larger_than_payload() {
        // Assign claiming u32::MAX chunks in a tiny payload must not allocate.
        let mut payload = Vec::new();
        put_u32(&mut payload, 1); // round
        put_f64(&mut payload, 0.5);
        put_u32(&mut payload, u32::MAX); // absurd count
        let len = (payload.len() + 2) as u32;
        let mut bytes = Vec::new();
        put_u32(&mut bytes, len);
        bytes.push(WIRE_VERSION);
        bytes.push(TAG_ASSIGN);
        bytes.extend_from_slice(&payload);
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Truncated)));
    }

    #[test]
    fn frame_buffer_reassembles_byte_at_a_time() {
        let frames = all_frames();
        let mut wire_bytes = Vec::new();
        for f in &frames {
            wire_bytes.extend_from_slice(&f.encode());
        }
        let mut fb = FrameBuffer::new();
        let mut out = Vec::new();
        for &b in &wire_bytes {
            fb.feed(&[b]);
            while let Some(f) = fb.next_frame().unwrap() {
                out.push(f);
            }
        }
        assert_eq!(out, frames);
        assert!(fb.is_empty());
    }

    #[test]
    fn frame_buffer_handles_bulk_and_partial_mixes() {
        let frames = all_frames();
        let mut wire_bytes = Vec::new();
        for f in &frames {
            wire_bytes.extend_from_slice(&f.encode());
        }
        // feed everything except the last byte: all but the final frame
        let mut fb = FrameBuffer::new();
        fb.feed(&wire_bytes[..wire_bytes.len() - 1]);
        let mut out = Vec::new();
        while let Some(f) = fb.next_frame().unwrap() {
            out.push(f);
        }
        assert_eq!(out.len(), frames.len() - 1);
        assert!(!fb.is_empty());
        fb.feed(&wire_bytes[wire_bytes.len() - 1..]);
        assert_eq!(fb.next_frame().unwrap().unwrap(), *frames.last().unwrap());
        assert!(fb.next_frame().unwrap().is_none());
        assert!(fb.is_empty());
    }

    #[test]
    fn frame_buffer_rejects_bad_length_before_buffering_payload() {
        let mut fb = FrameBuffer::new();
        fb.feed(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(matches!(fb.next_frame(), Err(WireError::BadLength(_))));
    }

    #[test]
    fn f32_tensor_payloads_are_bit_exact_including_nan() {
        let specials = vec![0.0f32, -0.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1e-38];
        let f = Frame::Params { job: 0, version: 1, off: 0, total: 6, data: specials.clone() };
        match Frame::decode(&f.encode()).unwrap() {
            Frame::Params { data, .. } => {
                assert_eq!(data.len(), specials.len());
                for (a, b) in data.iter().zip(&specials) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_lying_tensor_totals_without_allocating() {
        // total beyond the hard cap
        let f = Frame::Params { job: 0, version: 1, off: 0, total: 4, data: vec![1.0; 4] };
        let mut bytes = f.encode();
        // layout: 4 len + 1 ver + 1 tag + 4 job + 4 version + 4 off, then total
        let total_off = 4 + 1 + 1 + 4 + 4 + 4;
        bytes[total_off..total_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadLength(_))));
        // float count larger than the payload holds
        let count_off = total_off + 4;
        let mut lying = f.encode();
        lying[count_off..count_off + 4].copy_from_slice(&(1u32 << 20).to_le_bytes());
        assert!(matches!(Frame::decode(&lying), Err(WireError::Truncated)));
        // a slice overrunning its declared total
        let short = Frame::Params { job: 0, version: 1, off: 3, total: 4, data: vec![1.0; 4] };
        assert!(Frame::decode(&short.encode()).is_err());
    }

    #[test]
    fn rejects_hostile_grad_unit_counts() {
        let f = Frame::GradAssign {
            job: 0,
            round: 1,
            param_version: 0,
            work_units: 0.25,
            units: vec![GradUnit::Coded { job: 1, terms: vec![(0, 1.0)] }],
        };
        let base = f.encode();
        // layout: 4 len + 1 ver + 1 tag + 4 job + 4 round + 4 ver + 8 wu, then count
        let count_off = 4 + 1 + 1 + 4 + 4 + 4 + 8;
        for hostile in [1000u32, 1 << 24, u32::MAX] {
            let mut bytes = base.clone();
            bytes[count_off..count_off + 4].copy_from_slice(&hostile.to_le_bytes());
            assert!(
                matches!(Frame::decode(&bytes), Err(WireError::Truncated)),
                "hostile unit count {hostile} decoded"
            );
        }
        // hostile term count inside the coded unit
        let term_count_off = count_off + 4 + 1 + 4;
        let mut bytes = base.clone();
        bytes[term_count_off..term_count_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Truncated)));
    }

    #[test]
    fn error_frame_bounds_its_message() {
        // an over-long message is truncated on encode…
        let long = "x".repeat(MAX_ERROR_MSG + 500);
        let f = Frame::Error { code: ERR_BAD_HANDSHAKE, msg: long };
        match Frame::decode(&f.encode()).unwrap() {
            Frame::Error { code, msg } => {
                assert_eq!(code, ERR_BAD_HANDSHAKE);
                assert_eq!(msg.len(), MAX_ERROR_MSG);
            }
            other => panic!("{other:?}"),
        }
        // …and a lying length prefix is rejected on decode
        let ok = Frame::Error { code: 1, msg: "hi".into() };
        let mut bytes = ok.encode();
        let len_off = 4 + 1 + 1 + 1;
        bytes[len_off..len_off + 4].copy_from_slice(&(MAX_ERROR_MSG as u32 + 1).to_le_bytes());
        assert!(Frame::decode(&bytes).is_err());
    }

    #[test]
    fn submit_frame_bounds_its_strings() {
        // over-long name/spec are truncated on encode (at a char
        // boundary) so the frame always re-decodes…
        let f = Frame::Submit {
            name: "n".repeat(MAX_JOB_NAME + 30),
            scheme: "é".repeat(MAX_SUBMIT_SPEC), // 2 bytes per char
            session_jobs: 1,
            priority: 255,
        };
        match Frame::decode(&f.encode()).unwrap() {
            Frame::Submit { name, scheme, .. } => {
                assert_eq!(name.len(), MAX_JOB_NAME);
                assert!(scheme.len() <= MAX_SUBMIT_SPEC);
                assert!(scheme.chars().all(|c| c == 'é'), "char split on truncation");
            }
            other => panic!("{other:?}"),
        }
        // …and a lying name-length prefix is rejected on decode without
        // allocating
        let ok = Frame::Submit {
            name: "a".into(),
            scheme: "gc:1".into(),
            session_jobs: 2,
            priority: 0,
        };
        let mut bytes = ok.encode();
        let name_len_off = 4 + 1 + 1;
        for hostile in [MAX_JOB_NAME as u32 + 1, u32::MAX] {
            bytes[name_len_off..name_len_off + 4].copy_from_slice(&hostile.to_le_bytes());
            assert!(matches!(Frame::decode(&bytes), Err(WireError::Truncated)));
        }
        // Rejected reasons are bounded like Error messages
        let loud = Frame::Rejected { reason: "r".repeat(MAX_ERROR_MSG + 9) };
        match Frame::decode(&loud.encode()).unwrap() {
            Frame::Rejected { reason } => assert_eq!(reason.len(), MAX_ERROR_MSG),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn f64_bit_exact() {
        for x in [0.0, -0.0, 1.5e-300, f64::MAX, 0.1 + 0.2] {
            let f = Frame::Assign { round: 0, work_units: x, chunks: vec![] };
            match Frame::decode(&f.encode()).unwrap() {
                Frame::Assign { work_units, .. } => {
                    assert_eq!(work_units.to_bits(), x.to_bits())
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
