//! Length-prefixed binary wire protocol between the fleet master and its
//! workers (no external serialization deps — hand-rolled little-endian
//! codec, versioned and bounds-checked).
//!
//! Frame layout on the wire:
//!
//! ```text
//! ┌────────────┬─────────┬─────┬────────────────┐
//! │ len: u32le │ ver: u8 │ tag │ payload        │
//! └────────────┴─────────┴─────┴────────────────┘
//!       len = 2 + payload length (covers ver + tag + payload)
//! ```
//!
//! Integers are little-endian; `f64` travels as its IEEE-754 bit pattern.
//! A reader rejects frames whose version byte is not [`WIRE_VERSION`],
//! whose length exceeds [`MAX_FRAME_LEN`], or whose payload is truncated
//! or over-long for the tag — a malformed peer can never make the master
//! allocate unboundedly or mis-parse.

use std::io::{self, Read, Write};

/// Protocol version; bump on any incompatible frame change.
pub const WIRE_VERSION: u8 = 1;

/// Upper bound on one frame's `len` field (1 MiB): an `Assign` for a
/// full-replication task at n = 4096 chunks is still < 20 KiB.
pub const MAX_FRAME_LEN: u32 = 1 << 20;

/// Everything that can go wrong decoding a frame.
#[derive(Debug)]
pub enum WireError {
    /// Underlying stream error.
    Io(io::Error),
    /// Peer closed the connection cleanly at a frame boundary.
    Closed,
    /// Version byte mismatch.
    BadVersion(u8),
    /// Unknown frame tag.
    BadTag(u8),
    /// Payload shorter than its tag requires.
    Truncated,
    /// Payload longer than its tag requires.
    TrailingBytes,
    /// Declared length outside `[2, MAX_FRAME_LEN]`.
    BadLength(u32),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io error: {e}"),
            WireError::Closed => write!(f, "connection closed"),
            WireError::BadVersion(v) => {
                write!(f, "wire version {v} (expected {WIRE_VERSION})")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::Truncated => write!(f, "truncated frame payload"),
            WireError::TrailingBytes => write!(f, "trailing bytes after frame payload"),
            WireError::BadLength(l) => write!(f, "bad frame length {l}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// A protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Worker → master on connect: claim a worker slot.
    Hello { worker_id: u32 },
    /// Master → worker: execute one round's task. `work_units` is the
    /// normalized load (what the latency of the task scales with);
    /// `chunks` are the data-chunk ids the task covers (the synthetic
    /// minitask folds them into its checksum; a real workload would load
    /// them).
    Assign { round: u32, work_units: f64, chunks: Vec<u32> },
    /// Worker → master: one round's result. `compute_s` is the worker's
    /// own execution-time measurement (diagnostic only — the master
    /// trusts its wall-clock arrival observation, never the worker's
    /// clock); `checksum` proves the minitask ran.
    Result { worker_id: u32, round: u32, compute_s: f64, checksum: u64 },
    /// Worker → master: liveness signal between results.
    Heartbeat { worker_id: u32, round: u32 },
    /// Master → worker: exit the serve loop.
    Shutdown,
}

const TAG_HELLO: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_RESULT: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => TAG_HELLO,
            Frame::Assign { .. } => TAG_ASSIGN,
            Frame::Result { .. } => TAG_RESULT,
            Frame::Heartbeat { .. } => TAG_HEARTBEAT,
            Frame::Shutdown => TAG_SHUTDOWN,
        }
    }

    /// Encode to the on-wire byte sequence (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        match self {
            Frame::Hello { worker_id } => put_u32(&mut payload, *worker_id),
            Frame::Assign { round, work_units, chunks } => {
                put_u32(&mut payload, *round);
                put_f64(&mut payload, *work_units);
                put_u32(&mut payload, chunks.len() as u32);
                for &c in chunks {
                    put_u32(&mut payload, c);
                }
            }
            Frame::Result { worker_id, round, compute_s, checksum } => {
                put_u32(&mut payload, *worker_id);
                put_u32(&mut payload, *round);
                put_f64(&mut payload, *compute_s);
                put_u64(&mut payload, *checksum);
            }
            Frame::Heartbeat { worker_id, round } => {
                put_u32(&mut payload, *worker_id);
                put_u32(&mut payload, *round);
            }
            Frame::Shutdown => {}
        }
        let len = (payload.len() + 2) as u32;
        let mut out = Vec::with_capacity(4 + len as usize);
        put_u32(&mut out, len);
        out.push(WIRE_VERSION);
        out.push(self.tag());
        out.extend_from_slice(&payload);
        out
    }

    /// Decode one frame from its full on-wire bytes (length prefix
    /// included). The inverse of [`encode`](Self::encode).
    pub fn decode(bytes: &[u8]) -> Result<Frame, WireError> {
        let mut cur = Cursor { buf: bytes, pos: 0 };
        let len = cur.u32()?;
        if len < 2 || len > MAX_FRAME_LEN {
            return Err(WireError::BadLength(len));
        }
        if bytes.len() != 4 + len as usize {
            return Err(if bytes.len() < 4 + len as usize {
                WireError::Truncated
            } else {
                WireError::TrailingBytes
            });
        }
        Self::decode_body(&bytes[4..])
    }

    /// Decode the body (version + tag + payload, no length prefix).
    fn decode_body(body: &[u8]) -> Result<Frame, WireError> {
        let mut cur = Cursor { buf: body, pos: 0 };
        let version = cur.u8()?;
        if version != WIRE_VERSION {
            return Err(WireError::BadVersion(version));
        }
        let tag = cur.u8()?;
        let frame = match tag {
            TAG_HELLO => Frame::Hello { worker_id: cur.u32()? },
            TAG_ASSIGN => {
                let round = cur.u32()?;
                let work_units = cur.f64()?;
                let count = cur.u32()? as usize;
                // a chunk id is 4 bytes; reject counts the payload cannot hold
                if count > cur.remaining() / 4 {
                    return Err(WireError::Truncated);
                }
                let chunks = (0..count).map(|_| cur.u32()).collect::<Result<_, _>>()?;
                Frame::Assign { round, work_units, chunks }
            }
            TAG_RESULT => Frame::Result {
                worker_id: cur.u32()?,
                round: cur.u32()?,
                compute_s: cur.f64()?,
                checksum: cur.u64()?,
            },
            TAG_HEARTBEAT => Frame::Heartbeat { worker_id: cur.u32()?, round: cur.u32()? },
            TAG_SHUTDOWN => Frame::Shutdown,
            t => return Err(WireError::BadTag(t)),
        };
        if cur.remaining() != 0 {
            return Err(WireError::TrailingBytes);
        }
        Ok(frame)
    }
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    w.write_all(&frame.encode())?;
    w.flush()
}

/// Read one frame from a stream. Returns [`WireError::Closed`] if the
/// peer closed the connection at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut len_buf = [0u8; 4];
    // distinguish clean EOF (0 bytes) from mid-frame truncation
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) => {
                return Err(if filled == 0 { WireError::Closed } else { WireError::Truncated })
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len < 2 || len > MAX_FRAME_LEN {
        return Err(WireError::BadLength(len));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    })?;
    Frame::decode_body(&body)
}

/// Incremental frame assembler for non-blocking readers.
///
/// A readiness-driven master reads whatever bytes the socket has —
/// which may be half a frame, or three frames and the length prefix of
/// a fourth. `FrameBuffer` accumulates those bytes ([`feed`](Self::feed))
/// and hands back complete frames one at a time
/// ([`next_frame`](Self::next_frame)), leaving any trailing partial
/// frame buffered for the next readiness event. The same bounds checks
/// as [`read_frame`] apply: a declared length outside
/// `[2, MAX_FRAME_LEN]` is rejected before any payload is buffered
/// past it, so a malformed peer cannot force unbounded buffering.
#[derive(Debug, Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before `start` belong to frames already
    /// returned (compacted away on the next `feed`).
    start: usize,
}

impl FrameBuffer {
    /// Empty buffer.
    pub fn new() -> Self {
        FrameBuffer { buf: Vec::new(), start: 0 }
    }

    /// Append raw bytes read off the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.start > 0 {
            // compact the consumed prefix before growing
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Extract the next complete frame, if the buffer holds one.
    ///
    /// `Ok(None)` means "need more bytes"; an `Err` is fatal for the
    /// connection (the stream can no longer be framed).
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = &self.buf[self.start..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(avail[..4].try_into().unwrap());
        if len < 2 || len > MAX_FRAME_LEN {
            return Err(WireError::BadLength(len));
        }
        let total = 4 + len as usize;
        if avail.len() < total {
            return Ok(None);
        }
        let frame = Frame::decode_body(&avail[4..total])?;
        self.start += total;
        if self.start == self.buf.len() {
            self.buf.clear();
            self.start = 0;
        }
        Ok(Some(frame))
    }

    /// Bytes buffered but not yet returned as frames.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.start
    }

    /// No partial frame is buffered.
    pub fn is_empty(&self) -> bool {
        self.pending_bytes() == 0
    }
}

// --- little-endian primitives -----------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_le_bytes());
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Cursor<'_> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, k: usize) -> Result<&[u8], WireError> {
        if self.remaining() < k {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + k];
        self.pos += k;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { worker_id: 7 },
            Frame::Assign { round: 3, work_units: 0.125, chunks: vec![0, 5, 255] },
            Frame::Assign { round: 1, work_units: 0.0, chunks: vec![] },
            Frame::Result { worker_id: 2, round: 3, compute_s: 0.0421, checksum: 0xdead_beef },
            Frame::Heartbeat { worker_id: 9, round: 12 },
            Frame::Shutdown,
        ]
    }

    #[test]
    fn round_trips_every_frame() {
        for f in all_frames() {
            let bytes = f.encode();
            assert_eq!(Frame::decode(&bytes).unwrap(), f, "frame {f:?}");
        }
    }

    #[test]
    fn stream_round_trips_back_to_back() {
        let frames = all_frames();
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        assert!(matches!(read_frame(&mut r), Err(WireError::Closed)));
    }

    #[test]
    fn rejects_bad_version() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[4] = WIRE_VERSION + 1;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadVersion(_))));
    }

    #[test]
    fn rejects_unknown_tag() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[5] = 0xff;
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadTag(0xff))));
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes = Frame::Hello { worker_id: 1 }.encode();
        assert!(matches!(Frame::decode(&bytes[..bytes.len() - 1]), Err(WireError::Truncated)));
        let mut long = bytes.clone();
        long.push(0);
        assert!(matches!(Frame::decode(&long), Err(WireError::TrailingBytes)));
        // trailing bytes inside the declared payload are also rejected
        let mut padded = Frame::Shutdown.encode();
        padded[0] += 1; // declared length grows by one…
        padded.push(0); // …and the byte exists, but Shutdown has no payload
        assert!(matches!(Frame::decode(&padded), Err(WireError::TrailingBytes)));
    }

    #[test]
    fn rejects_oversize_length() {
        let mut bytes = Frame::Shutdown.encode();
        bytes[..4].copy_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(matches!(Frame::decode(&bytes), Err(WireError::BadLength(_))));
        let mut r = &bytes[..];
        assert!(matches!(read_frame(&mut r), Err(WireError::BadLength(_))));
    }

    #[test]
    fn rejects_chunk_count_larger_than_payload() {
        // Assign claiming u32::MAX chunks in a tiny payload must not allocate.
        let mut payload = Vec::new();
        put_u32(&mut payload, 1); // round
        put_f64(&mut payload, 0.5);
        put_u32(&mut payload, u32::MAX); // absurd count
        let len = (payload.len() + 2) as u32;
        let mut bytes = Vec::new();
        put_u32(&mut bytes, len);
        bytes.push(WIRE_VERSION);
        bytes.push(TAG_ASSIGN);
        bytes.extend_from_slice(&payload);
        assert!(matches!(Frame::decode(&bytes), Err(WireError::Truncated)));
    }

    #[test]
    fn frame_buffer_reassembles_byte_at_a_time() {
        let frames = all_frames();
        let mut wire_bytes = Vec::new();
        for f in &frames {
            wire_bytes.extend_from_slice(&f.encode());
        }
        let mut fb = FrameBuffer::new();
        let mut out = Vec::new();
        for &b in &wire_bytes {
            fb.feed(&[b]);
            while let Some(f) = fb.next_frame().unwrap() {
                out.push(f);
            }
        }
        assert_eq!(out, frames);
        assert!(fb.is_empty());
    }

    #[test]
    fn frame_buffer_handles_bulk_and_partial_mixes() {
        let frames = all_frames();
        let mut wire_bytes = Vec::new();
        for f in &frames {
            wire_bytes.extend_from_slice(&f.encode());
        }
        // feed everything except the last byte: all but the final frame
        let mut fb = FrameBuffer::new();
        fb.feed(&wire_bytes[..wire_bytes.len() - 1]);
        let mut out = Vec::new();
        while let Some(f) = fb.next_frame().unwrap() {
            out.push(f);
        }
        assert_eq!(out.len(), frames.len() - 1);
        assert!(!fb.is_empty());
        fb.feed(&wire_bytes[wire_bytes.len() - 1..]);
        assert_eq!(fb.next_frame().unwrap().unwrap(), *frames.last().unwrap());
        assert!(fb.next_frame().unwrap().is_none());
        assert!(fb.is_empty());
    }

    #[test]
    fn frame_buffer_rejects_bad_length_before_buffering_payload() {
        let mut fb = FrameBuffer::new();
        fb.feed(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(matches!(fb.next_frame(), Err(WireError::BadLength(_))));
    }

    #[test]
    fn f64_bit_exact() {
        for x in [0.0, -0.0, 1.5e-300, f64::MAX, 0.1 + 0.2] {
            let f = Frame::Assign { round: 0, work_units: x, chunks: vec![] };
            match Frame::decode(&f.encode()).unwrap() {
                Frame::Assign { work_units, .. } => {
                    assert_eq!(work_units.to_bits(), x.to_bits())
                }
                other => panic!("{other:?}"),
            }
        }
    }
}
