//! Real distributed worker fleet: TCP master/worker execution backend.
//!
//! This is the "real workers" backend the sans-IO session was designed
//! for — the stand-in for the paper's 256-worker AWS Lambda fleet, with
//! the μ-rule applied to **wall-clock** arrival times instead of
//! simulated ones:
//!
//! * [`wire`] — length-prefixed, versioned binary frames
//!   (Hello/Assign/Result/Heartbeat/Shutdown), no external deps;
//! * [`worker`] — the `sgc worker` runtime: connects to a master, serves
//!   task assignments, executes synthetic minitask workloads, and injects
//!   deterministic, seeded chaos (Gilbert–Elliot straggle states with
//!   Pareto slowdowns) so live runs are reproducible;
//! * [`reactor`] — the single-threaded readiness layer: a hand-rolled
//!   `poll(2)` binding plus non-blocking buffered [`Connection`]s (no
//!   `mio`, no external deps);
//! * [`master`] — [`FleetCluster`]: one reactor thread owns the
//!   listener and every worker socket, streams per-worker completions
//!   through the [`EventCluster`](crate::cluster::EventCluster) API,
//!   and manages the elastic roster (late joins, reconnects, reaping;
//!   [`MembershipConfig`]); the
//!   [`JobScheduler`](crate::sched::JobScheduler) pumps each session's
//!   incremental
//!   [`try_close_round`](crate::session::SgcSession::try_close_round)
//!   off that stream, so stragglers are cut the moment the wall clock
//!   passes the μ-cutoff — without waiting for all `n` results — and
//!   many sessions can multiplex over one fleet;
//! * [`loopback`] — an in-process harness spinning a master plus `n`
//!   worker threads over localhost (tests, CI smoke, `sgc run --fleet N`),
//!   including the late-join path
//!   ([`join_worker`](LoopbackFleet::join_worker)).
//!
//! See `rust/DESIGN.md` §Fleet, §Reactor and §Membership for wire-frame
//! layout, the event-loop ownership model, exact-wakeup math,
//! heartbeat/failure semantics and the membership state machine;
//! `rust/docs/OPERATIONS.md` is the operator runbook.

pub mod loopback;
pub mod master;
pub mod reactor;
pub mod wire;
pub mod worker;

pub use loopback::LoopbackFleet;
pub use master::{drive_fleet, FleetCluster, FleetRun, MembershipConfig};
pub use reactor::Connection;
pub use wire::{Frame, FrameBuffer, WireError, WIRE_VERSION};
pub use worker::{run_worker, ChaosConfig, WorkerConfig, WorkerStats};
