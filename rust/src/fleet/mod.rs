//! Real distributed worker fleet: TCP master/worker execution backend.
//!
//! This is the "real workers" backend the sans-IO session was designed
//! for — the stand-in for the paper's 256-worker AWS Lambda fleet, with
//! the μ-rule applied to **wall-clock** arrival times instead of
//! simulated ones:
//!
//! * [`wire`] — length-prefixed, versioned binary frames
//!   (Hello/Assign/Result/Heartbeat/Shutdown), no external deps;
//! * [`worker`] — the `sgc worker` runtime: connects to a master, serves
//!   task assignments, executes synthetic minitask workloads, and injects
//!   deterministic, seeded chaos (Gilbert–Elliot straggle states with
//!   Pareto slowdowns) so live runs are reproducible;
//! * [`master`] — [`FleetCluster`]: accepts worker connections and
//!   streams per-worker completions as they arrive through the
//!   [`EventCluster`](crate::cluster::EventCluster) API; the
//!   [`JobScheduler`](crate::sched::JobScheduler) pumps each session's
//!   incremental
//!   [`try_close_round`](crate::session::SgcSession::try_close_round)
//!   off that stream, so stragglers are cut the moment the wall clock
//!   passes the μ-cutoff — without waiting for all `n` results — and
//!   many sessions can multiplex over one fleet;
//! * [`loopback`] — an in-process harness spinning a master plus `n`
//!   worker threads over localhost (tests, CI smoke, `sgc run --fleet N`).
//!
//! See `rust/DESIGN.md` §Fleet for wire-frame layout, heartbeat/failure
//! semantics and the wall-clock vs simulated μ-rule discussion.

pub mod loopback;
pub mod master;
pub mod wire;
pub mod worker;

pub use loopback::LoopbackFleet;
pub use master::{drive_fleet, FleetCluster, FleetRun};
pub use wire::{Frame, WireError, WIRE_VERSION};
pub use worker::{run_worker, ChaosConfig, WorkerConfig, WorkerStats};
