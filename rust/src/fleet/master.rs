//! Master side of the fleet: accept worker connections, stream arrivals,
//! and drive the session with the μ-rule applied to **wall-clock** time.
//!
//! Unlike the simulator backends — which hand the session all `n`
//! completion times at once — [`FleetCluster::run_round`] submits each
//! worker's result the moment its `Result` frame arrives, polls
//! [`SgcSession::try_close_round`] with the elapsed wall clock, and
//! sleeps only until the session's
//! [`deadline_hint`](SgcSession::deadline_hint) (the `(1+μ)·κ` cutoff).
//! The round therefore closes the instant the μ-rule and the wait-out
//! policy allow — a straggler that would take 10× the round time costs
//! the master nothing beyond the cutoff, exactly like the paper's Lambda
//! master.
//!
//! **Failure semantics.** Workers heartbeat between results. A worker
//! whose socket drops or whose heartbeats go stale is marked dead; the
//! μ-rule cuts it like any straggler, and the run only errors when the
//! wait-out policy *needs* a dead worker (the pattern cannot conform
//! without it) — at that point no amount of waiting can help.

use super::wire::{read_frame, write_frame, Frame};
use super::worker::chunk_checksum;
use crate::cluster::{Cluster, RoundSample, RunTrace};
use crate::coding::{SchemeConfig, TaskDesc, WorkUnit};
use crate::coordinator::metrics::RunReport;
use crate::session::{RoundPlan, SessionConfig, SessionEvent, SgcSession};
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a connection reader observed.
enum Event {
    Frame { worker: usize, frame: Frame, at: Instant },
    Gone { worker: usize },
}

/// One worker's connection (write half; reads happen on a side thread).
struct Conn {
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
}

/// The fleet master's cluster handle: `n` connected workers plus the
/// arrival stream. Implements [`Cluster`] (collect everything — used by
/// trace recording and as a drop-in backend) and the streaming
/// [`run_round`](Self::run_round) that the μ-rule path uses.
pub struct FleetCluster {
    n: usize,
    conns: Vec<Conn>,
    events: Receiver<Event>,
    last_seen: Vec<Instant>,
    /// Worker is currently considered unusable. Set by a dropped socket
    /// (`gone`), a bad checksum (`byzantine`), or stale heartbeats — the
    /// last is *recoverable*: a fresh frame from a non-gone,
    /// non-byzantine worker clears it (a transient stall on a loaded box
    /// must not permanently evict a healthy worker).
    dead: Vec<bool>,
    /// Socket-level death (connection dropped / write failed): permanent.
    gone: Vec<bool>,
    /// Worker returned a result that fails checksum verification:
    /// permanent — nothing it sends is trusted again.
    byzantine: Vec<bool>,
    /// Stale-heartbeat threshold.
    heartbeat_timeout: Duration,
    /// Hard cap on one round's wall-clock time — a worker that
    /// heartbeats but never returns its result would otherwise livelock
    /// a wait-out that needs it.
    round_timeout: Duration,
    /// Wall-clock start per assigned round (index = round - 1).
    round_starts: Vec<Instant>,
    /// Trace under construction: every arrival lands here, including
    /// results for rounds the μ-rule already closed.
    finish_log: Vec<Vec<Option<f64>>>,
    loads_log: Vec<Vec<f64>>,
    /// Which workers actually received each round's `Assign` (a worker
    /// dead at assign time is skipped and can never fill that round's
    /// slot, even if its `dead` flag later clears).
    assigned_log: Vec<Vec<bool>>,
    /// Expected `Result` checksum per round per worker (recomputed from
    /// the assigned chunks); a mismatching result is byzantine.
    sum_log: Vec<Vec<u64>>,
    shut_down: bool,
}

impl FleetCluster {
    /// Bind `addr` and wait for `n` workers to connect and claim
    /// distinct slots via `Hello`.
    pub fn listen(addr: &str, n: usize, accept_timeout: Duration) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("fleet master: bind {addr}: {e}"))?;
        Self::accept_on(listener, n, accept_timeout)
    }

    /// Bind an ephemeral localhost port, hand the bound address to
    /// `spawn_workers` (which starts the workers pointing at it), then
    /// accept all `n`. See [`LoopbackFleet`](super::LoopbackFleet) for
    /// the packaged version.
    pub fn listen_ephemeral(
        n: usize,
        accept_timeout: Duration,
        spawn_workers: impl FnOnce(&str),
    ) -> crate::Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        spawn_workers(&addr);
        Self::accept_on(listener, n, accept_timeout)
    }

    fn accept_on(
        listener: TcpListener,
        n: usize,
        accept_timeout: Duration,
    ) -> crate::Result<Self> {
        anyhow::ensure!(n > 0, "fleet needs at least one worker");
        let deadline = Instant::now() + accept_timeout;
        // Keep the handshake BufReader: a worker may already have queued
        // heartbeats behind its Hello, and any byte buffered here must
        // reach the reader thread or the wire stream desyncs.
        let mut slots: Vec<Option<(TcpStream, BufReader<TcpStream>)>> =
            (0..n).map(|_| None).collect();
        let mut connected = 0usize;
        listener.set_nonblocking(true)?;
        // Handshakes run on side threads: a stray connection that sends
        // nothing (port scanner, health check) must neither tear the
        // master down nor head-of-line-block honest workers.
        let (htx, hrx) = channel::<(String, crate::Result<HelloOutcome>)>();
        while connected < n {
            deadline.checked_duration_since(Instant::now()).ok_or_else(|| {
                anyhow::anyhow!("fleet master: only {connected}/{n} workers connected")
            })?;
            match listener.accept() {
                Ok((stream, peer)) => {
                    let htx = htx.clone();
                    std::thread::Builder::new()
                        .name("sgc-fleet-hello".into())
                        .spawn(move || {
                            let _ = htx.send((peer.to_string(), hello_handshake(stream)));
                        })
                        .expect("spawn handshake thread");
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => anyhow::bail!("fleet master: accept: {e}"),
            }
            while let Ok((peer, outcome)) = hrx.try_recv() {
                match outcome {
                    Ok((id, stream, reader)) if id < n && slots[id].is_none() => {
                        slots[id] = Some((stream, reader));
                        connected += 1;
                    }
                    Ok((id, _, _)) => {
                        eprintln!(
                            "fleet master: rejecting {peer}: bad or duplicate \
                             worker id {id} (fleet of {n})"
                        );
                    }
                    Err(e) => eprintln!("fleet master: rejecting {peer}: {e}"),
                }
            }
        }
        let (tx, rx) = channel();
        let conns = slots
            .into_iter()
            .enumerate()
            .map(|(worker, slot)| {
                let (stream, reader) = slot.expect("all slots filled");
                let handle = spawn_reader(worker, reader, tx.clone());
                Conn { stream, reader: Some(handle) }
            })
            .collect::<Vec<_>>();
        let now = Instant::now();
        Ok(FleetCluster {
            n,
            conns,
            events: rx,
            last_seen: vec![now; n],
            dead: vec![false; n],
            gone: vec![false; n],
            byzantine: vec![false; n],
            heartbeat_timeout: Duration::from_millis(1500),
            round_timeout: Duration::from_secs(60),
            round_starts: Vec::new(),
            finish_log: Vec::new(),
            loads_log: Vec::new(),
            assigned_log: Vec::new(),
            sum_log: Vec::new(),
            shut_down: false,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Workers currently considered dead.
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.dead[i]).collect()
    }

    /// Raise (or lower) the hard per-round wall-clock cap. Needed when
    /// worker task durations are configured long (`sgc worker --base-s`).
    pub fn set_round_timeout(&mut self, timeout: Duration) {
        self.round_timeout = timeout;
    }

    /// Execute one round with streaming arrivals: assign, submit results
    /// as they land, and close through the session's incremental μ-rule.
    /// Returns the close events (never `WaitingFor`).
    pub fn run_round(
        &mut self,
        session: &mut SgcSession,
        plan: &RoundPlan,
    ) -> crate::Result<Vec<SessionEvent>> {
        anyhow::ensure!(plan.tasks.len() == self.n, "plan/fleet size mismatch");
        let round = plan.round as u32;
        let start = Instant::now();
        self.round_starts.push(start);
        self.loads_log.push(plan.loads.clone());
        self.finish_log.push(vec![None; self.n]);
        self.assigned_log.push(vec![false; self.n]);
        self.sum_log.push(vec![0; self.n]);
        debug_assert_eq!(self.round_starts.len(), plan.round);

        for worker in 0..self.n {
            let chunks = chunk_ids(&plan.tasks[worker]);
            self.sum_log.last_mut().unwrap()[worker] = chunk_checksum(&chunks);
            if self.dead[worker] {
                continue; // μ-rule will cut it; wait-out may still error below
            }
            let frame =
                Frame::Assign { round, work_units: plan.loads[worker], chunks };
            if write_frame(&mut self.conns[worker].stream, &frame).is_err() {
                self.mark_gone(worker);
            } else {
                self.assigned_log.last_mut().unwrap()[worker] = true;
            }
        }

        loop {
            // Judge the round at `now_s`, but only after absorbing every
            // arrival already queued — an unprocessed result from before
            // the cutoff must not be cut as a straggler.
            let now_s = start.elapsed().as_secs_f64();
            while let Ok(ev) = self.events.try_recv() {
                self.absorb(ev, Some((&mut *session, round)));
            }
            let events = session.try_close_round(now_s);
            let waiting = match events.first() {
                Some(SessionEvent::WaitingFor { workers }) => workers.clone(),
                _ => return Ok(events),
            };
            // Hopeless only if every awaited worker can never submit —
            // dead, or never assigned this round — AND the wait is not
            // merely "the μ-cutoff has not passed yet": before the cutoff
            // the next try_close will cut them like ordinary stragglers.
            // With no submissions at all (hint unknown) they can never
            // produce κ either.
            let assigned = &self.assigned_log[plan.round - 1];
            let past_cutoff = match session.deadline_hint() {
                None => true,
                Some(hint) => now_s >= hint,
            };
            if past_cutoff && waiting.iter().all(|&w| self.dead[w] || !assigned[w]) {
                anyhow::bail!(
                    "round {}: workers {waiting:?} are dead or unassigned and the \
                     wait-out policy needs one of them; the straggler pattern cannot \
                     conform",
                    plan.round
                );
            }
            if start.elapsed() > self.round_timeout {
                anyhow::bail!(
                    "round {}: still waiting for workers {waiting:?} after {:?}",
                    plan.round,
                    self.round_timeout
                );
            }
            // Sleep until the μ-cutoff if it is still ahead; otherwise we
            // are waiting for a specific arrival — poll at heartbeat pace.
            // Either way, never sleep past the hard round cap.
            let cap = self
                .round_timeout
                .saturating_sub(start.elapsed())
                .max(Duration::from_millis(1));
            let timeout = match session.deadline_hint() {
                Some(hint) if hint > now_s => Duration::from_secs_f64(hint - now_s),
                _ => Duration::from_millis(25),
            }
            .min(cap);
            match self.events.recv_timeout(timeout) {
                Ok(ev) => self.absorb(ev, Some((&mut *session, round))),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("round {}: every worker connection dropped", plan.round)
                }
            }
            self.reap_stale_heartbeats();
        }
    }

    /// Process one reader event. When `current` is set, results for the
    /// open round are submitted into the session; results for earlier
    /// rounds only land in the trace log.
    fn absorb(&mut self, ev: Event, current: Option<(&mut SgcSession, u32)>) {
        match ev {
            Event::Frame { worker, frame, at } => {
                self.last_seen[worker] = at;
                // a live frame resurrects a stale-heartbeat false positive
                if self.dead[worker] && !self.gone[worker] && !self.byzantine[worker] {
                    self.dead[worker] = false;
                }
                if let Frame::Result { round: r, checksum, .. } = frame {
                    if self.byzantine[worker] {
                        return; // nothing from a byzantine worker is trusted
                    }
                    let idx = r as usize;
                    if idx >= 1 && idx <= self.round_starts.len() {
                        if checksum != self.sum_log[idx - 1][worker] {
                            // byzantine: the worker did not do the work it
                            // was assigned — never trust it again
                            eprintln!(
                                "fleet master: worker {worker} returned a bad \
                                 checksum for round {r}; marking it byzantine"
                            );
                            self.byzantine[worker] = true;
                            self.mark_dead(worker);
                            return;
                        }
                        let rel = at
                            .checked_duration_since(self.round_starts[idx - 1])
                            .map_or(0.0, |d| d.as_secs_f64())
                            .max(1e-9);
                        let slot = &mut self.finish_log[idx - 1][worker];
                        if slot.is_none() {
                            *slot = Some(rel);
                            if let Some((session, round)) = current {
                                if r == round {
                                    session.submit(worker, rel);
                                }
                            }
                        }
                    }
                }
            }
            Event::Gone { worker } => self.mark_gone(worker),
        }
    }

    fn mark_dead(&mut self, worker: usize) {
        self.dead[worker] = true;
    }

    /// Socket-level (permanent) death.
    fn mark_gone(&mut self, worker: usize) {
        self.gone[worker] = true;
        self.dead[worker] = true;
    }

    fn reap_stale_heartbeats(&mut self) {
        let now = Instant::now();
        for i in 0..self.n {
            if !self.dead[i]
                && now.duration_since(self.last_seen[i]) > self.heartbeat_timeout
            {
                self.dead[i] = true;
            }
        }
    }

    /// Drain late results until the trace matrix is complete (or
    /// `flush_timeout` passes), then return the recorded trace. Cut
    /// stragglers keep computing and report late, so a healthy fleet
    /// always completes its matrix. Entries of workers that died are
    /// synthesized past the round's `(1+μ)` cutoff (`mu` is the session's
    /// μ), so replaying the trace cuts them exactly like the live run
    /// did.
    pub fn finish_trace(&mut self, flush_timeout: Duration, mu: f64) -> RunTrace {
        let deadline = Instant::now() + flush_timeout;
        // only wait for slots a live worker could still fill — entries of
        // gone/byzantine workers and rounds never assigned to a worker
        // are synthesized below, and waiting on them would stall every
        // post-failure run for the whole timeout
        let incomplete = |fleet: &Self| {
            fleet.finish_log.iter().zip(&fleet.assigned_log).any(|(row, assigned)| {
                row.iter().enumerate().any(|(w, f)| {
                    f.is_none() && assigned[w] && !fleet.gone[w] && !fleet.byzantine[w]
                })
            })
        };
        while incomplete(self) && Instant::now() < deadline {
            match self.events.recv_timeout(Duration::from_millis(25)) {
                Ok(ev) => self.absorb(ev, None),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let mut trace = RunTrace::new(self.n);
        for (loads, finish) in self.loads_log.iter().zip(&self.finish_log) {
            let worst =
                finish.iter().flatten().cloned().fold(0.0f64, f64::max).max(1e-3);
            // strictly beyond any μ-cutoff: κ ≤ worst ⇒ (1+μ)·2·worst > (1+μ)·κ
            let missing_fill = (1.0 + mu.max(0.0)) * worst * 2.0;
            let row: Vec<f64> = finish.iter().map(|f| f.unwrap_or(missing_fill)).collect();
            trace.push(loads.clone(), row, None);
        }
        trace
    }

    /// Send `Shutdown` to every worker and close all sockets
    /// (idempotent). Closing unconditionally matters: a worker that was
    /// *falsely* marked dead (stalled heartbeats) is still blocked in
    /// its read loop and must see EOF to exit, or joining it hangs.
    pub fn shutdown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        for conn in &mut self.conns {
            let _ = write_frame(&mut conn.stream, &Frame::Shutdown);
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for FleetCluster {
    fn drop(&mut self) {
        self.shutdown(); // closes every socket → reader threads unblock
        for conn in &mut self.conns {
            if let Some(h) = conn.reader.take() {
                let _ = h.join();
            }
        }
    }
}

/// Blocking backend compatibility: wait for *every* worker's result.
/// This is the uncoded-friendly path; the μ-rule fleet path is
/// [`FleetCluster::run_round`]. Panics on a dead fleet — the `Cluster`
/// trait has no error channel; use [`drive_fleet`] for fallible driving.
///
/// The returned `state` is an all-false placeholder (a real fleet has no
/// ground truth), like [`crate::probe::ProfileCluster`]'s — so traces
/// recorded by wrapping this in a
/// [`RecordingCluster`](crate::cluster::RecordingCluster) carry no
/// straggler pattern. Prefer [`drive_fleet`], whose trace stores the
/// μ-rule detections instead.
impl Cluster for FleetCluster {
    fn n(&self) -> usize {
        self.n
    }

    fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        assert_eq!(loads.len(), self.n);
        let round = (self.round_starts.len() + 1) as u32;
        let start = Instant::now();
        self.round_starts.push(start);
        self.loads_log.push(loads.to_vec());
        self.finish_log.push(vec![None; self.n]);
        self.assigned_log.push(vec![true; self.n]);
        self.sum_log.push(vec![chunk_checksum(&[]); self.n]);
        for worker in 0..self.n {
            assert!(!self.dead[worker], "worker {worker} is dead");
            let frame =
                Frame::Assign { round, work_units: loads[worker], chunks: Vec::new() };
            write_frame(&mut self.conns[worker].stream, &frame)
                .unwrap_or_else(|e| panic!("assign to worker {worker}: {e}"));
        }
        let idx = round as usize - 1;
        while self.finish_log[idx].iter().any(|f| f.is_none()) {
            match self.events.recv_timeout(Duration::from_millis(100)) {
                Ok(ev) => self.absorb(ev, None),
                Err(RecvTimeoutError::Timeout) => {
                    self.reap_stale_heartbeats();
                    let gone = self.dead_workers();
                    assert!(gone.is_empty(), "workers {gone:?} died mid-round");
                }
                Err(RecvTimeoutError::Disconnected) => panic!("all workers disconnected"),
            }
        }
        RoundSample {
            finish: self.finish_log[idx].iter().map(|f| f.unwrap()).collect(),
            state: vec![false; self.n],
        }
    }
}

/// The result of a fleet run: the protocol report plus the recorded
/// wall-clock delay trace (replayable via
/// [`RunTrace::replay`](crate::cluster::RunTrace::replay)).
pub struct FleetRun {
    pub report: RunReport,
    pub trace: RunTrace,
}

/// Drive one session over a fleet with streaming arrivals and the
/// wall-clock μ-rule, collecting the delay trace along the way.
pub fn drive_fleet(
    scheme_cfg: &SchemeConfig,
    cfg: &SessionConfig,
    fleet: &mut FleetCluster,
) -> crate::Result<FleetRun> {
    let mut session = SgcSession::new(scheme_cfg, cfg.clone());
    anyhow::ensure!(
        fleet.n() == session.n(),
        "fleet has {} workers but scheme {} expects {}",
        fleet.n(),
        scheme_cfg.label(),
        session.n()
    );
    // The round log (and hence the trace) is per-fleet, not per-session:
    // a reused fleet would interleave two sessions' rounds and stall on
    // already-filled trace slots. Fail fast instead.
    anyhow::ensure!(
        fleet.round_starts.is_empty(),
        "FleetCluster is single-use: this fleet already executed {} rounds; \
         spawn a fresh fleet per run",
        fleet.round_starts.len()
    );
    // One plan buffer reused across all rounds (§Perf).
    let mut plan = RoundPlan::default();
    while !session.is_complete() {
        session.begin_round_into(&mut plan);
        fleet.run_round(&mut session, &plan)?;
    }
    let mut trace = fleet.finish_trace(Duration::from_secs(10), cfg.mu);
    let report = session.into_report();
    // A real fleet has no ground-truth straggler states; record the
    // μ-rule detections instead so the trace's pattern feeds
    // `SimCluster::from_trace` like a simulator trace does.
    for (tr, row) in trace.rounds.iter_mut().zip(&report.detected_pattern.rows) {
        tr.state = Some(row.clone());
    }
    Ok(FleetRun { report, trace })
}

/// Chunk ids a task touches (what `Assign` ships to the worker).
fn chunk_ids(task: &TaskDesc) -> Vec<u32> {
    let mut out = Vec::new();
    for unit in &task.units {
        match unit {
            WorkUnit::Noop => {}
            WorkUnit::Plain { chunk, .. } => out.push(*chunk as u32),
            WorkUnit::Coded { chunks, .. } => {
                out.extend(chunks.iter().map(|&c| c as u32))
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// A completed handshake: claimed id, write half, and the (possibly
/// pre-filled) read half.
type HelloOutcome = (usize, TcpStream, BufReader<TcpStream>);

/// Complete one connection's `Hello` handshake (bounded at 5 s).
fn hello_handshake(stream: TcpStream) -> crate::Result<HelloOutcome> {
    // BSD-family accept() inherits the listener's O_NONBLOCK; this
    // connection must block (with a read timeout) for the handshake.
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    match read_frame(&mut reader) {
        Ok(Frame::Hello { worker_id }) => {
            stream.set_read_timeout(None)?;
            Ok((worker_id as usize, stream, reader))
        }
        Ok(other) => anyhow::bail!("expected Hello, got {other:?}"),
        Err(e) => anyhow::bail!("reading Hello: {e}"),
    }
}

fn spawn_reader(
    worker: usize,
    mut reader: BufReader<TcpStream>,
    tx: Sender<Event>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sgc-fleet-read-{worker}"))
        .spawn(move || {
            loop {
                match read_frame(&mut reader) {
                    Ok(frame) => {
                        let at = Instant::now();
                        if tx.send(Event::Frame { worker, frame, at }).is_err() {
                            break; // master dropped
                        }
                    }
                    // Closed and any other error both end the connection
                    Err(_) => {
                        let _ = tx.send(Event::Gone { worker });
                        break;
                    }
                }
            }
        })
        .expect("spawn fleet reader")
}
