//! Master side of the fleet: a **single-threaded readiness reactor**
//! that owns every worker socket and exposes the arrival stream as an
//! [`EventCluster`] — the wall-clock backend behind the multi-job
//! [`JobScheduler`](crate::sched::JobScheduler).
//!
//! There is no thread per connection and no fixed-interval sleep
//! anywhere on this path: one [`poll(2)`](super::reactor::poll_fds)
//! call watches the listener, every live worker socket and every
//! pre-`Hello` pending connection at once, and its timeout is the
//! *exact* distance to the next deadline — the caller's μ-cutoff
//! horizon, a heartbeat reap, a round's hard cap, or a handshake
//! expiry. [`FleetCluster::poll`] therefore wakes either because a
//! socket produced bytes or because a deadline arrived, never because
//! a sleep slice ended; that is what lets one master thread hold a
//! paper-scale fleet and makes the wall-clock μ-rule cutoff exact
//! (see `rust/DESIGN.md` §Reactor).
//!
//! **Elastic membership.** The listener stays open after startup:
//! a worker that sends `Hello` mid-run is admitted into the live
//! roster ([`ClusterEvent::WorkerJoined`]), and a worker whose socket
//! drops, that goes byzantine, or whose heartbeats stay silent past
//! the reap deadline is permanently retired
//! ([`ClusterEvent::WorkerRetired`]) — its slot id may be reclaimed by
//! a fresh `Hello` (a reconnect), unless it was byzantine. The
//! [`JobScheduler`](crate::sched::JobScheduler) observes those events
//! and re-places in-flight sessions onto the live set instead of
//! waiting out ghosts. [`MembershipConfig`] holds the join-window and
//! reap knobs (`sgc serve --join-window --reap-after`); see
//! `rust/DESIGN.md` §Membership for the state machine.
//!
//! **Failure semantics.** Workers heartbeat between results. A worker
//! whose socket drops (or that returns a byzantine result) is reported
//! as [`ClusterEvent::WorkerDead`] for every submission it still owes;
//! the μ-rule cuts it like any straggler, and a run only errors when the
//! wait-out policy *needs* a dead worker (the pattern cannot conform
//! without it) — at that point no amount of waiting can help. Stale
//! heartbeats are *recoverable* (a fresh frame clears them), so they
//! pause new assignments but are never reported as deaths; a stall that
//! never recovers is bounded by the hard per-round cap, which emits
//! [`ClusterEvent::RoundTimeout`] once per submission, and by the much
//! longer reap deadline, which retires the worker for good.

use super::reactor::{poll_fds, Connection, PollFd, POLLIN, POLLOUT};
use super::wire::{
    tensor_slices, Frame, GradUnit, TensorAssembly, WireError, ERR_BAD_HANDSHAKE,
    ERR_BAD_VERSION, WIRE_VERSION,
};
use super::worker::chunk_checksum;
use crate::chaos::{FaultKind, ResolvedPlan};
use crate::cluster::{ClusterEvent, EventCluster, JobId, RunTrace};
use crate::coding::SchemeConfig;
use crate::coordinator::metrics::RunReport;
use crate::grad::dataplane::SharedDataPlane;
use crate::obs::{Counter, EventKind, Histogram, Obs};
use crate::sched::{ControlQueue, RawSubmit, RawVerdict, SharedControl};
use crate::session::SessionConfig;
use crate::{log_info, log_warn};
use std::collections::{BTreeSet, HashMap};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Lifetime budget of *phantom* slots (gap ids a join may skip over):
/// a `Hello` claiming an id past the current capacity creates vacant
/// slots for the skipped ids, each consuming one unit of this budget —
/// so no sequence of rogue `Hello`s can ratchet the slot table by more
/// than this beyond the genuinely-joined ids. Sequential joins
/// (`id == capacity`) cost nothing.
const MAX_JOIN_GAP: usize = 64;

/// Concurrent `/metrics` scrape connections the reactor will hold; new
/// connections past this are refused at accept (a Prometheus server
/// scrapes one at a time — this bounds misbehaving pollers).
const MAX_SCRAPES: usize = 32;

/// Byte cap on a scrape request head; anything longer is not a scrape.
const MAX_SCRAPE_REQ: usize = 8 * 1024;

/// Concurrent job-submission connections the reactor will hold; new
/// control connections past this are refused at accept (each submits
/// once and leaves — this bounds misbehaving clients, not throughput).
const MAX_CTRL_CONNS: usize = 32;

/// Wake-slop histogram bounds: a healthy reactor overshoots its poll
/// deadline by well under a millisecond; the tail buckets make a loaded
/// or descheduled box visible.
const SLOP_BUCKETS: [f64; 10] =
    [1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.25];

/// Membership and liveness policy of an elastic fleet.
#[derive(Clone, Copy, Debug)]
pub struct MembershipConfig {
    /// How long after startup late `Hello`s are still admitted into the
    /// roster (measured from the end of the initial accept). `None`
    /// keeps the fleet elastic forever — the default.
    pub join_window: Option<Duration>,
    /// Stale-heartbeat threshold: silence past this pauses new
    /// assignments to the worker but is *recoverable* (any fresh frame
    /// clears it).
    pub heartbeat_timeout: Duration,
    /// Silence past this retires the worker permanently (the reap
    /// policy). Must be well above `heartbeat_timeout`.
    pub reap_after: Duration,
    /// A pending connection must complete its `Hello` within this.
    pub hello_timeout: Duration,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        MembershipConfig {
            join_window: None,
            heartbeat_timeout: Duration::from_millis(1500),
            reap_after: Duration::from_secs(10),
            hello_timeout: Duration::from_secs(5),
        }
    }
}

/// One worker slot of the roster.
struct WorkerSlot {
    /// The connection, while the worker is live.
    conn: Option<Connection>,
    /// In the live roster (drives `Assign` fan-out and membership
    /// accounting). `false` + `conn: None` = retired or never joined.
    live: bool,
    /// A worker ever claimed this slot (distinguishes a retired worker
    /// from a phantom gap slot created by an out-of-order join id).
    ever_joined: bool,
    /// Heartbeats stale (recoverable): skip new `Assign`s, report no
    /// deaths — a transient stall on a loaded box must not evict a
    /// healthy worker.
    stale: bool,
    /// Returned a result failing verification — a bad synthetic
    /// checksum, or a gradient payload the decode audit pinned on this
    /// worker: permanent — nothing it sends is trusted again, and the
    /// slot id can never be reclaimed.
    byzantine: bool,
    last_seen: Instant,
    /// Jobs whose `JobSpec` went out on the *current* connection
    /// (cleared on every admit: a fresh socket knows nothing).
    sent_specs: BTreeSet<u32>,
    /// `(job, chunk)` partitions delivered on the current connection.
    sent_chunks: BTreeSet<(u32, u32)>,
    /// Latest parameter version broadcast per job on the current
    /// connection.
    sent_params: HashMap<u32, u32>,
    /// In-flight `GradResult` reassembly, keyed `(job, wire round)`.
    grad_asm: HashMap<(u32, u32), TensorAssembly>,
}

impl WorkerSlot {
    fn vacant(now: Instant) -> Self {
        WorkerSlot {
            conn: None,
            live: false,
            ever_joined: false,
            stale: false,
            byzantine: false,
            last_seen: now,
            sent_specs: BTreeSet::new(),
            sent_chunks: BTreeSet::new(),
            sent_params: HashMap::new(),
            grad_asm: HashMap::new(),
        }
    }

    /// Eligible for new `Assign`s right now.
    fn usable(&self) -> bool {
        self.live && !self.stale && !self.byzantine
    }
}

/// A connection that has not yet completed its `Hello`.
struct PendingConn {
    conn: Connection,
    peer: String,
    since: Instant,
    /// Readiness observed by the last reactor turn (also set on accept,
    /// so a `Hello` that raced ahead of the poll is picked up).
    ready: bool,
}

/// Who owns an entry of the reactor's fd set.
enum Owner {
    Listener,
    Slot(usize),
    Pending(usize),
    /// The `/metrics` listener (when serving).
    Metrics,
    /// An in-flight scrape connection.
    Scrape(usize),
    /// The job-submission listener (when serving).
    Jobs,
    /// An in-flight job-submission (control) connection.
    Control(usize),
}

/// One in-flight job-submission connection on the control socket,
/// serviced by the same reactor that drives the workers: one `Submit`
/// frame in, one `Accepted`/`Rejected` (or `Error`) farewell out, then
/// the socket closes.
struct CtrlConn {
    conn: Connection,
    peer: String,
    /// Token of the forwarded [`RawSubmit`], once one was accepted off
    /// this connection; the matching verdict closes the connection.
    token: Option<u64>,
    /// Farewell queued: drain the write buffer, then reap.
    done: bool,
}

/// Metric handles and the shared journal for the fleet layer (see
/// [`crate::obs`]). Handles are registered once in
/// [`FleetCluster::set_obs`]; the reactor's hot path only touches them.
struct FleetObs {
    obs: Arc<Obs>,
    bytes_in: Counter,
    bytes_out: Counter,
    joins: Counter,
    retires: Counter,
    stale_marks: Counter,
    scrapes: Counter,
    wake_slop: Histogram,
    /// Master-side time to enqueue one job's parameter broadcast.
    param_broadcast: Histogram,
    /// Per-job `sgc_grad_bytes_total` handles, created on first use.
    grad_bytes: HashMap<u32, Counter>,
}

impl FleetObs {
    /// The `sgc_grad_bytes_total{job=...}` counter for `job`.
    fn grad_bytes_counter(&mut self, job: u32) -> &Counter {
        let obs = &self.obs;
        self.grad_bytes.entry(job).or_insert_with(|| {
            obs.metrics.counter(
                "sgc_grad_bytes_total",
                &format!("job=\"{job}\""),
                "Gradient payload bytes received from workers",
            )
        })
    }
}

/// One in-flight HTTP scrape connection, serviced by the same reactor
/// that drives the workers (no extra thread): bytes are read on
/// `POLLIN` until the request head completes, then the rendered
/// exposition is written out on `POLLOUT` and the socket closed.
struct Scrape {
    conn: TcpStream,
    req: Vec<u8>,
    resp: Vec<u8>,
    /// Written prefix of `resp`.
    wpos: usize,
    /// Request parsed; now draining `resp`.
    responding: bool,
    /// Finished or failed; reaped at the end of the turn.
    closed: bool,
}

/// The fleet master's cluster handle: an elastic roster of worker
/// connections plus the arrival stream, implementing [`EventCluster`]
/// on a single I/O thread. Blocking callers wrap it in
/// [`SyncAdapter`](crate::cluster::SyncAdapter); fallible streaming
/// runs go through [`drive_fleet`] or a
/// [`JobScheduler`](crate::sched::JobScheduler).
pub struct FleetCluster {
    listener: Option<TcpListener>,
    addr: String,
    slots: Vec<WorkerSlot>,
    pending: Vec<PendingConn>,
    membership: MembershipConfig,
    /// Initial fleet size (ids admitted during the startup accept).
    initial_n: usize,
    /// Remaining lifetime budget of phantom gap slots (see
    /// [`MAX_JOIN_GAP`]).
    phantom_budget: usize,
    /// Initial accept finished; joins from here on stage events.
    started: bool,
    /// Hard cap on one submission's wall-clock time — a worker that
    /// heartbeats but never returns its result would otherwise livelock
    /// a wait-out that needs it.
    round_timeout: Duration,
    /// The fleet's time origin (`now_s` axis).
    clock_start: Instant,
    /// Wall-clock start per submission (index = wire round id - 1).
    round_starts: Vec<Instant>,
    /// Owning `(job, round)` per submission — the wire protocol carries
    /// only the sequence number; this is the multiplexing map back.
    seq_jobs: Vec<(JobId, u64)>,
    /// Trace under construction: every arrival lands here, including
    /// results for rounds the μ-rule already closed. Rows are sized to
    /// the capacity at submit time (joins only widen later rows).
    finish_log: Vec<Vec<Option<f64>>>,
    loads_log: Vec<Vec<f64>>,
    /// Which workers actually received each submission's `Assign` (a
    /// worker unusable at assign time is skipped and can never fill
    /// that round's slot, even if it later recovers).
    assigned_log: Vec<Vec<bool>>,
    /// Expected `Result` checksum per submission per worker; a
    /// mismatching result is byzantine.
    sum_log: Vec<Vec<u64>>,
    /// `WorkerDead` already emitted for (submission, worker).
    dead_notified: Vec<Vec<bool>>,
    /// `RoundTimeout` already emitted per submission.
    timeout_emitted: Vec<bool>,
    /// First submission that might still owe a timeout check.
    timeout_scan_from: usize,
    /// Events translated but not yet handed out by `poll`.
    staged: Vec<ClusterEvent>,
    /// The batch the last `poll` returned (swap-recycled with `staged`).
    delivered: Vec<ClusterEvent>,
    /// Reactor fd-set scratch, reused across turns.
    pollfds: Vec<PollFd>,
    owners: Vec<Owner>,
    shut_down: bool,
    /// Observability hub, when attached (see [`Self::set_obs`]).
    obs: Option<FleetObs>,
    /// Listener for `/metrics` scrapes, when serving.
    metrics_listener: Option<TcpListener>,
    /// In-flight scrape connections.
    scrapes: Vec<Scrape>,
    /// Listener for job submissions (`sgc serve --listen-jobs`).
    jobs_listener: Option<TcpListener>,
    /// In-flight job-submission connections.
    ctrl_conns: Vec<CtrlConn>,
    /// The master ↔ serving-loop handoff queue, once
    /// [`serve_jobs`](Self::serve_jobs) opened the control socket.
    control: Option<SharedControl>,
    /// Next submission token (also the verdict correlation key).
    next_ctrl_token: u64,
    /// Scripted master-side fault plan, when injected (see
    /// [`Self::set_chaos`]).
    chaos: Option<FleetChaos>,
    /// The gradient data plane, when real-gradient jobs are served (see
    /// [`Self::set_dataplane`]).
    dp: Option<SharedDataPlane>,
    /// `GradAssign` fan-out per submission per worker (for mid-round
    /// rejoin replay, mirroring the synthetic `Assign` replay).
    grad_assign_log: Vec<HashMap<usize, Frame>>,
}

/// Master-side chaos state: the resolved plan plus the per-worker
/// partition windows currently in force.
struct FleetChaos {
    plan: ResolvedPlan,
    /// Inbound frames from worker `w` are discarded while
    /// `submissions() < drop_until[w]` (submission ordinals, 1-based
    /// like the wire `round` field).
    drop_until: Vec<u64>,
}

/// The distinct chunk ids a set of wire units touches (what a worker
/// must hold to serve them).
fn units_chunks(units: &[GradUnit]) -> Vec<u32> {
    let mut set = BTreeSet::new();
    for u in units {
        match u {
            GradUnit::Plain { chunk, .. } => {
                set.insert(*chunk);
            }
            GradUnit::Coded { terms, .. } => {
                for &(c, _) in terms {
                    set.insert(c);
                }
            }
        }
    }
    set.into_iter().collect()
}

impl FleetCluster {
    /// Bind `addr` and wait for `n` workers to connect and claim
    /// distinct slots via `Hello`.
    pub fn listen(addr: &str, n: usize, accept_timeout: Duration) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("fleet master: bind {addr}: {e}"))?;
        Self::accept_on(listener, n, accept_timeout)
    }

    /// Bind an ephemeral localhost port, hand the bound address to
    /// `spawn_workers` (which starts the workers pointing at it), then
    /// accept all `n`. See [`LoopbackFleet`](super::LoopbackFleet) for
    /// the packaged version.
    pub fn listen_ephemeral(
        n: usize,
        accept_timeout: Duration,
        spawn_workers: impl FnOnce(&str),
    ) -> crate::Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        spawn_workers(&addr);
        Self::accept_on(listener, n, accept_timeout)
    }

    fn accept_on(
        listener: TcpListener,
        n: usize,
        accept_timeout: Duration,
    ) -> crate::Result<Self> {
        anyhow::ensure!(n > 0, "fleet needs at least one worker");
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?.to_string();
        let now = Instant::now();
        let mut fleet = FleetCluster {
            listener: Some(listener),
            addr,
            slots: (0..n).map(|_| WorkerSlot::vacant(now)).collect(),
            pending: Vec::new(),
            membership: MembershipConfig::default(),
            initial_n: n,
            phantom_budget: MAX_JOIN_GAP,
            started: false,
            round_timeout: Duration::from_secs(60),
            clock_start: now,
            round_starts: Vec::new(),
            seq_jobs: Vec::new(),
            finish_log: Vec::new(),
            loads_log: Vec::new(),
            assigned_log: Vec::new(),
            sum_log: Vec::new(),
            dead_notified: Vec::new(),
            timeout_emitted: Vec::new(),
            timeout_scan_from: 0,
            staged: Vec::new(),
            delivered: Vec::new(),
            pollfds: Vec::new(),
            owners: Vec::new(),
            shut_down: false,
            obs: None,
            metrics_listener: None,
            scrapes: Vec::new(),
            jobs_listener: None,
            ctrl_conns: Vec::new(),
            control: None,
            next_ctrl_token: 1,
            chaos: None,
            dp: None,
            grad_assign_log: Vec::new(),
        };
        let deadline = Instant::now() + accept_timeout;
        while fleet.live_workers() < n {
            let now = Instant::now();
            if now >= deadline {
                anyhow::bail!(
                    "fleet master: only {}/{n} workers connected",
                    fleet.live_workers()
                );
            }
            let wake = fleet.next_wakeup(Some(deadline)).unwrap_or(deadline);
            fleet.reactor_turn(Some(wake.saturating_duration_since(now)));
            fleet.process_pending();
        }
        // Fresh time origin: admissions above staged nothing (started is
        // false), and `now_s` starts at the instant the fleet is whole.
        fleet.started = true;
        fleet.clock_start = Instant::now();
        for slot in &mut fleet.slots {
            slot.last_seen = fleet.clock_start;
        }
        Ok(fleet)
    }

    /// Current worker-slot capacity (live + retired + never-reclaimed),
    /// i.e. the length `submit` expects of its `loads`. Grows when a
    /// worker joins with a fresh id; never shrinks.
    pub fn n(&self) -> usize {
        self.slots.len()
    }

    /// Workers currently in the live roster.
    pub fn live_workers(&self) -> usize {
        self.slots.iter().filter(|s| s.live).count()
    }

    /// The address workers connect to (late joiners included).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Submissions executed so far (wire-level rounds).
    pub fn submissions(&self) -> usize {
        self.round_starts.len()
    }

    /// Workers currently unusable for new assignments (stale heartbeats
    /// or retired).
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| !self.slots[i].usable()).collect()
    }

    /// Raise (or lower) the hard per-round wall-clock cap. Needed when
    /// worker task durations are configured long (`sgc worker --base-s`).
    pub fn set_round_timeout(&mut self, timeout: Duration) {
        self.round_timeout = timeout;
    }

    /// Replace the membership policy (join window, heartbeat and reap
    /// deadlines). Takes effect from the next `poll`.
    pub fn set_membership(&mut self, membership: MembershipConfig) {
        self.membership = membership;
    }

    /// Inject the master-side half of a scripted chaos plan (see
    /// [`crate::chaos`]): at each scripted submission ordinal, a
    /// [`FaultKind::Shrink`] retires its victims before the fan-out, and
    /// a [`FaultKind::Partition`] discards the victims' inbound frames —
    /// results *and* heartbeats — for the plan's partition window, so
    /// the stale-heartbeat machinery sees a real network hole. The
    /// worker-side kinds (crash, hang, byzantine, reconnect) are acted
    /// out by the workers themselves via
    /// [`WorkerConfig::fault`](super::WorkerConfig); this side only
    /// journals and reacts.
    pub fn set_chaos(&mut self, plan: ResolvedPlan) {
        let n = self.slots.len();
        self.chaos = Some(FleetChaos { plan, drop_until: vec![0; n] });
    }

    /// Act out the master-side faults scripted for submission `seq`
    /// (called at the top of every `submit`, before the fan-out).
    fn apply_chaos(&mut self, seq: u64) {
        let Some(ch) = &self.chaos else { return };
        let window = ch.plan.partition_rounds;
        let mut acts: Vec<(FaultKind, usize)> = Vec::new();
        for f in ch.plan.master_faults() {
            if f.round == seq {
                for &w in &f.workers {
                    acts.push((f.kind, w));
                }
            }
        }
        for (kind, w) in acts {
            if let Some(fo) = &self.obs {
                fo.obs.journal.record(
                    self.clock_start.elapsed().as_secs_f64(),
                    EventKind::ChaosFault,
                    -1,
                    seq as i64,
                    w as i64,
                    f64::from(kind.discriminant()),
                );
            }
            log_warn!(
                "fleet master: chaos {kind:?} hits worker {w} at submission {seq}"
            );
            match kind {
                FaultKind::Shrink => {
                    if w < self.slots.len() {
                        self.retire(w, "chaos shrink");
                    }
                }
                _ => {
                    let du =
                        &mut self.chaos.as_mut().expect("chaos checked above").drop_until;
                    if du.len() <= w {
                        du.resize(w + 1, 0);
                    }
                    du[w] = seq + window;
                }
            }
        }
    }

    /// Queue a frame on worker `w`'s connection. `false` if the worker
    /// has no connection or the write failed fatally.
    fn send_to(&mut self, w: usize, frame: &Frame) -> bool {
        match &mut self.slots[w].conn {
            Some(c) => c.send(frame),
            None => false,
        }
    }

    /// Ship everything worker `w`'s current connection is missing before
    /// a `GradAssign` of `job` pinned at parameter `version`: the
    /// `JobSpec` (once per connection), the partitions backing `needed`
    /// chunks, and the parameter broadcast. Delivery is tracked per
    /// connection, so a reconnect or late join re-ships from scratch
    /// (the worker's `off == 0` assembly restart makes that idempotent)
    /// while steady-state rounds cost one `Params` sweep per optimizer
    /// step and nothing else. Returns `false` on a write failure (the
    /// caller retires the worker).
    fn ship_grad_prereqs(&mut self, w: usize, job: u32, version: u32, needed: &[u32]) -> bool {
        let Some(dp) = self.dp.clone() else { return false };
        let guard = dp.lock().expect("data plane lock poisoned");
        let Some(jd) = guard.job(job) else { return false };
        let ts = self.clock_start.elapsed().as_secs_f64();
        if !self.slots[w].sent_specs.contains(&job) {
            let d = jd.dims;
            let frame = Frame::JobSpec {
                job,
                input: d.input as u32,
                classes: d.classes as u32,
                hidden1: d.hidden1 as u32,
                hidden2: d.hidden2 as u32,
            };
            if !self.send_to(w, &frame) {
                return false;
            }
            self.slots[w].sent_specs.insert(job);
        }
        for &chunk in needed {
            if self.slots[w].sent_chunks.contains(&(job, chunk)) {
                continue;
            }
            let Some(cd) = jd.chunks.get(chunk as usize) else { continue };
            let flat = cd.flat();
            let total = flat.len() as u32;
            for (off, slice) in tensor_slices(&flat) {
                let frame = Frame::Partition {
                    job,
                    chunk,
                    rows: cd.rows as u32,
                    off,
                    total,
                    data: slice.to_vec(),
                };
                if !self.send_to(w, &frame) {
                    return false;
                }
            }
            self.slots[w].sent_chunks.insert((job, chunk));
            if let Some(fo) = &self.obs {
                fo.obs.journal.record(
                    ts,
                    EventKind::PartitionSent,
                    job as i64,
                    -1,
                    w as i64,
                    flat.len() as f64,
                );
            }
        }
        if self.slots[w].sent_params.get(&job) != Some(&version) {
            let Some(params) = jd.params_at(version) else {
                // replaying a round staged too many optimizer steps ago:
                // the connection is fine, the worker just sits it out
                log_warn!(
                    "fleet master: job {job} params v{version} no longer retained; \
                     worker {w} will stay silent this round"
                );
                return true;
            };
            let t0 = Instant::now();
            let total = params.len() as u32;
            for (off, slice) in tensor_slices(params) {
                let frame = Frame::Params { job, version, off, total, data: slice.to_vec() };
                if !self.send_to(w, &frame) {
                    return false;
                }
            }
            self.slots[w].sent_params.insert(job, version);
            if let Some(fo) = &self.obs {
                fo.param_broadcast.record(t0.elapsed().as_secs_f64());
                fo.obs.journal.record(
                    ts,
                    EventKind::ParamBroadcast,
                    job as i64,
                    -1,
                    w as i64,
                    f64::from(version),
                );
            }
        }
        true
    }

    /// Retire workers the decode pass flagged as byzantine (a gradient
    /// payload inconsistent with the code's redundancy, pinned by the
    /// audit) — the gradient-plane analogue of the synthetic checksum
    /// check. Runs every reactor turn; draining an empty flag list is a
    /// lock-and-swap.
    fn drain_grad_flags(&mut self) {
        let Some(dp) = self.dp.clone() else { return };
        let flagged = dp.lock().expect("data plane lock poisoned").take_flagged();
        for w in flagged {
            if w < self.slots.len() && !self.slots[w].byzantine {
                log_warn!(
                    "fleet master: worker {w} failed the gradient redundancy audit; \
                     marking it byzantine"
                );
                self.slots[w].byzantine = true;
                self.retire(w, "byzantine gradient payload");
            }
        }
    }

    /// Attach an observability hub (see [`crate::obs`]): frame byte
    /// counters, membership counters and the reactor wake-slop
    /// histogram, plus journal entries for joins, retirements, stale
    /// marks and I/O. Share the same [`Obs`] with the
    /// [`JobScheduler`](crate::sched::JobScheduler) so one `/metrics`
    /// page covers both layers.
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        let m = &obs.metrics;
        let bytes_in =
            m.counter("sgc_frame_bytes_in_total", "", "Bytes read from worker sockets");
        let bytes_out =
            m.counter("sgc_frame_bytes_out_total", "", "Bytes written to worker sockets");
        let joins = m.counter("sgc_worker_joined_total", "", "Workers admitted mid-run");
        let retires =
            m.counter("sgc_worker_retired_total", "", "Workers permanently retired");
        let stale_marks = m.counter(
            "sgc_heartbeat_stale_total",
            "",
            "Recoverable stale-heartbeat transitions",
        );
        let scrapes =
            m.counter("sgc_metrics_scrapes_total", "", "HTTP /metrics requests served");
        let wake_slop = m.histogram_with_buckets(
            "sgc_reactor_wake_slop_seconds",
            "",
            "Reactor wake overshoot past the computed poll(2) deadline",
            &SLOP_BUCKETS,
        );
        let param_broadcast = m.histogram(
            "sgc_param_broadcast_seconds",
            "",
            "Master-side time to enqueue one job's parameter broadcast",
        );
        self.obs = Some(FleetObs {
            obs,
            bytes_in,
            bytes_out,
            joins,
            retires,
            stale_marks,
            scrapes,
            wake_slop,
            param_broadcast,
            grad_bytes: HashMap::new(),
        });
    }

    /// Attach the gradient data plane (see [`crate::grad`]): submissions
    /// of jobs with a staged round entry fan out `JobSpec` / `Partition`
    /// / `Params` / [`Frame::GradAssign`] instead of the synthetic
    /// `Assign`, and inbound [`Frame::GradResult`] slices are
    /// reassembled into the plane's staged entries. Share the same
    /// handle with the [`JobScheduler`](crate::sched::JobScheduler)
    /// (which stages the rounds) and the
    /// [`GradPump`](crate::grad::GradPump) (which decodes them).
    pub fn set_dataplane(&mut self, dp: SharedDataPlane) {
        self.dp = Some(dp);
    }

    /// Serve Prometheus text-format metrics on `addr` from the reactor
    /// itself: the scrape listener and every scrape connection join the
    /// same `poll(2)` fd set as the worker sockets — no extra thread,
    /// no lock shared with one. Returns the bound address (useful with
    /// port `0`). Installs a private [`Obs`] if none was attached yet;
    /// call [`set_obs`](Self::set_obs) first to share one.
    pub fn serve_metrics(&mut self, addr: &str) -> crate::Result<String> {
        if self.obs.is_none() {
            self.set_obs(Arc::new(Obs::new()));
        }
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("metrics endpoint: bind {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?.to_string();
        self.metrics_listener = Some(listener);
        Ok(bound)
    }

    /// Serve the job-submission control socket on `addr` from the
    /// reactor itself, exactly like [`serve_metrics`](Self::serve_metrics):
    /// the listener and every control connection are just more `Owner`s
    /// in the single `poll(2)` fd set. Inbound [`Frame::Submit`]s are
    /// queued on a [`ControlQueue`]; the serving loop
    /// ([`JobScheduler::serve`](crate::sched::JobScheduler::serve) with a
    /// [`QueueSource`](crate::sched::QueueSource)) drains them and posts
    /// verdicts that the reactor answers as [`Frame::Accepted`] /
    /// [`Frame::Rejected`]. Returns the bound address (useful with port
    /// `0`). Grab the shared queue with [`control`](Self::control).
    pub fn serve_jobs(&mut self, addr: &str) -> crate::Result<String> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("job endpoint: bind {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let bound = listener.local_addr()?.to_string();
        self.jobs_listener = Some(listener);
        if self.control.is_none() {
            self.control = Some(ControlQueue::shared());
        }
        Ok(bound)
    }

    /// The shared admission queue backing the control socket, once
    /// [`serve_jobs`](Self::serve_jobs) has been called. Hand this to a
    /// [`QueueSource`](crate::sched::QueueSource) so the serving loop
    /// sees the reactor's submissions.
    pub fn control(&self) -> Option<SharedControl> {
        self.control.clone()
    }

    /// The shared observability hub, when one is attached.
    pub fn obs(&self) -> Option<&Arc<Obs>> {
        self.obs.as_ref().map(|fo| &fo.obs)
    }

    /// Late `Hello`s are currently admissible.
    fn joins_open(&self) -> bool {
        if self.shut_down || self.listener.is_none() {
            return false;
        }
        if !self.started {
            return true; // initial accept
        }
        match self.membership.join_window {
            None => true,
            Some(w) => self.clock_start.elapsed() <= w,
        }
    }

    // --- the reactor -----------------------------------------------------

    /// One reactor turn: build the fd set (listener + worker sockets +
    /// pending handshakes), sleep in a single `poll(2)` bounded by
    /// `timeout`, then service every ready fd. With nothing to watch the
    /// turn degenerates to a precise bounded sleep.
    fn reactor_turn(&mut self, timeout: Option<Duration>) {
        self.deliver_ctrl_verdicts();
        self.pollfds.clear();
        self.owners.clear();
        if self.joins_open() {
            if let Some(l) = &self.listener {
                self.pollfds.push(PollFd::new(l.as_raw_fd(), POLLIN));
                self.owners.push(Owner::Listener);
            }
        }
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(c) = &slot.conn {
                self.pollfds.push(PollFd::new(c.fd(), c.interest()));
                self.owners.push(Owner::Slot(i));
            }
        }
        for (i, p) in self.pending.iter().enumerate() {
            self.pollfds.push(PollFd::new(p.conn.fd(), POLLIN));
            self.owners.push(Owner::Pending(i));
        }
        if let Some(l) = &self.metrics_listener {
            self.pollfds.push(PollFd::new(l.as_raw_fd(), POLLIN));
            self.owners.push(Owner::Metrics);
        }
        for (i, s) in self.scrapes.iter().enumerate() {
            let interest = if s.responding { POLLOUT } else { POLLIN };
            self.pollfds.push(PollFd::new(s.conn.as_raw_fd(), interest));
            self.owners.push(Owner::Scrape(i));
        }
        if let Some(l) = &self.jobs_listener {
            self.pollfds.push(PollFd::new(l.as_raw_fd(), POLLIN));
            self.owners.push(Owner::Jobs);
        }
        for (i, c) in self.ctrl_conns.iter().enumerate() {
            self.pollfds.push(PollFd::new(c.conn.fd(), c.conn.interest()));
            self.owners.push(Owner::Control(i));
        }
        if self.pollfds.is_empty() {
            if let Some(t) = timeout {
                if !t.is_zero() {
                    let _ = poll_fds(&mut [], Some(t));
                }
            }
            return;
        }
        if poll_fds(&mut self.pollfds, timeout).is_err() {
            return;
        }
        let owners = std::mem::take(&mut self.owners);
        let pollfds = std::mem::take(&mut self.pollfds);
        for (fd, owner) in pollfds.iter().zip(&owners) {
            match owner {
                Owner::Listener => {
                    if fd.readable() {
                        self.accept_ready();
                    }
                }
                Owner::Slot(i) => {
                    if fd.readable() {
                        self.read_slot(*i);
                    }
                    if fd.writable() {
                        self.flush_slot(*i);
                    }
                }
                Owner::Pending(i) => {
                    if fd.ready() {
                        if let Some(p) = self.pending.get_mut(*i) {
                            p.ready = true;
                        }
                    }
                }
                Owner::Metrics => {
                    if fd.readable() {
                        self.accept_scrapes();
                    }
                }
                Owner::Scrape(i) => {
                    if fd.ready() {
                        self.service_scrape(*i);
                    }
                }
                Owner::Jobs => {
                    if fd.readable() {
                        self.accept_ctrl();
                    }
                }
                Owner::Control(i) => {
                    if fd.readable() {
                        self.read_ctrl(*i);
                    }
                    if fd.writable() {
                        self.flush_ctrl(*i);
                    }
                }
            }
        }
        self.owners = owners;
        self.pollfds = pollfds;
        self.scrapes.retain(|s| !s.closed);
        self.reap_ctrl();
        self.collect_io();
    }

    /// Accept queued scrape connections (bounded by [`MAX_SCRAPES`]).
    fn accept_scrapes(&mut self) {
        loop {
            let Some(listener) = &self.metrics_listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.scrapes.len() >= MAX_SCRAPES
                        || stream.set_nonblocking(true).is_err()
                    {
                        continue; // refused: dropping the stream closes it
                    }
                    self.scrapes.push(Scrape {
                        conn: stream,
                        req: Vec::new(),
                        resp: Vec::new(),
                        wpos: 0,
                        responding: false,
                        closed: false,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Advance one scrape: accumulate the request head, then drain the
    /// rendered response.
    fn service_scrape(&mut self, i: usize) {
        let Some(s) = self.scrapes.get_mut(i) else { return };
        if s.responding {
            while s.wpos < s.resp.len() {
                match s.conn.write(&s.resp[s.wpos..]) {
                    Ok(0) => {
                        s.closed = true;
                        return;
                    }
                    Ok(k) => s.wpos += k,
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        s.closed = true;
                        return;
                    }
                }
            }
            s.closed = true; // response fully written
            return;
        }
        let mut tmp = [0u8; 1024];
        loop {
            match s.conn.read(&mut tmp) {
                Ok(0) => {
                    s.closed = true;
                    return;
                }
                Ok(k) => {
                    s.req.extend_from_slice(&tmp[..k]);
                    if s.req.len() > MAX_SCRAPE_REQ {
                        s.closed = true; // not an HTTP scrape
                        return;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    s.closed = true;
                    return;
                }
            }
        }
        if s.req.windows(4).any(|w| w == b"\r\n\r\n") {
            self.scrape_respond(i);
        }
    }

    /// Build the HTTP response for a completed request head and switch
    /// the scrape to its write phase.
    fn scrape_respond(&mut self, i: usize) {
        let request_line = {
            let req = &self.scrapes[i].req;
            let end = req.iter().position(|&b| b == b'\r').unwrap_or(req.len());
            String::from_utf8_lossy(&req[..end]).into_owned()
        };
        let metrics_get = request_line.starts_with("GET /metrics ")
            || request_line.starts_with("GET /metrics\r")
            || request_line == "GET /metrics";
        let (status, body) = if metrics_get {
            let body = self
                .obs
                .as_ref()
                .map(|fo| fo.obs.metrics.render_prometheus())
                .unwrap_or_default();
            ("200 OK", body)
        } else {
            ("404 Not Found", String::from("only GET /metrics is served here\n"))
        };
        if let Some(fo) = &self.obs {
            fo.scrapes.inc();
        }
        let mut resp = format!(
            "HTTP/1.0 {status}\r\n\
             Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\n\
             Connection: close\r\n\r\n",
            body.len()
        );
        resp.push_str(&body);
        let s = &mut self.scrapes[i];
        s.resp = resp.into_bytes();
        s.wpos = 0;
        s.responding = true;
        // opportunistic flush: most expositions fit one socket buffer
        self.service_scrape(i);
    }

    /// Accept queued control connections (bounded by
    /// [`MAX_CTRL_CONNS`]). A control client speaks the worker wire
    /// protocol but its whole conversation is one `Submit` in, one
    /// `Accepted` / `Rejected` / `Error` out.
    fn accept_ctrl(&mut self) {
        loop {
            let Some(listener) = &self.jobs_listener else { return };
            match listener.accept() {
                Ok((stream, peer)) => {
                    if self.ctrl_conns.len() >= MAX_CTRL_CONNS {
                        continue; // refused: dropping the stream closes it
                    }
                    if let Ok(conn) = Connection::new(stream) {
                        self.ctrl_conns.push(CtrlConn {
                            conn,
                            peer: peer.to_string(),
                            token: None,
                            done: false,
                        });
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Advance one control connection: parse its `Submit`, queue it for
    /// the serving loop, farewell protocol violators.
    fn read_ctrl(&mut self, i: usize) {
        let Some(c) = self.ctrl_conns.get_mut(i) else { return };
        let alive = c.conn.fill();
        if c.done {
            return; // draining until the verdict flushes; ignore extra bytes
        }
        match c.conn.try_next_frame() {
            Ok(Some(Frame::Submit { name, scheme, session_jobs, priority })) => {
                let token = self.next_ctrl_token;
                self.next_ctrl_token += 1;
                c.token = Some(token);
                if let Some(ctrl) = &self.control {
                    ctrl.lock()
                        .expect("control queue lock poisoned")
                        .incoming
                        .push_back(RawSubmit { token, name, scheme, session_jobs, priority });
                } else {
                    // serve_jobs always installs a queue; defensive only.
                    c.conn.send(&Frame::Rejected {
                        reason: "no serving loop attached".to_string(),
                    });
                    c.conn.flush();
                    c.done = true;
                }
            }
            Ok(Some(other)) => {
                log_warn!(
                    "fleet master: rejecting control peer {}: expected Submit, \
                     got {other:?}",
                    c.peer
                );
                c.conn.send(&Frame::Error {
                    code: ERR_BAD_HANDSHAKE,
                    msg: "expected Submit as the first frame".to_string(),
                });
                c.conn.flush();
                c.done = true;
            }
            Ok(None) => {
                if !alive || c.conn.is_dead() {
                    c.done = true;
                }
            }
            Err(WireError::BadVersion(v)) => {
                log_warn!(
                    "fleet master: rejecting control peer {}: wire version {v} \
                     (this master speaks v{WIRE_VERSION})",
                    c.peer
                );
                c.conn.send(&Frame::Error {
                    code: ERR_BAD_VERSION,
                    msg: format!(
                        "unsupported wire version {v}: this master speaks \
                         v{WIRE_VERSION}; upgrade the client"
                    ),
                });
                c.conn.flush();
                c.done = true;
            }
            Err(e) => {
                log_warn!(
                    "fleet master: rejecting control peer {}: malformed submit ({e})",
                    c.peer
                );
                c.conn.send(&Frame::Error {
                    code: ERR_BAD_HANDSHAKE,
                    msg: format!("malformed submission: {e}"),
                });
                c.conn.flush();
                c.done = true;
            }
        }
    }

    /// Drain a control connection's outbound buffer.
    fn flush_ctrl(&mut self, i: usize) {
        if let Some(c) = self.ctrl_conns.get_mut(i) {
            c.conn.flush();
        }
    }

    /// Answer every verdict the serving loop has posted: find the
    /// control connection that carried the matching token and send it
    /// `Accepted` / `Rejected` as its farewell.
    fn deliver_ctrl_verdicts(&mut self) {
        let Some(ctrl) = &self.control else { return };
        let verdicts: Vec<(u64, RawVerdict)> = {
            let mut q = ctrl.lock().expect("control queue lock poisoned");
            q.verdicts.drain(..).collect()
        };
        for (token, verdict) in verdicts {
            let Some(c) = self
                .ctrl_conns
                .iter_mut()
                .find(|c| c.token == Some(token) && !c.done)
            else {
                continue; // peer hung up before its verdict arrived
            };
            let frame = match verdict {
                RawVerdict::Accepted { job, queue_depth } => {
                    Frame::Accepted { job, queue_depth }
                }
                RawVerdict::Rejected { reason } => Frame::Rejected { reason },
            };
            c.conn.send(&frame);
            c.conn.flush();
            c.done = true;
        }
    }

    /// Drop control connections that have said their piece (verdict
    /// flushed) or died underneath us.
    fn reap_ctrl(&mut self) {
        let mut i = 0;
        while i < self.ctrl_conns.len() {
            let c = &self.ctrl_conns[i];
            if c.conn.is_dead() || (c.done && !c.conn.wants_write()) {
                self.ctrl_conns.swap_remove(i).conn.shutdown();
                continue; // swap_remove moved a new entry into `i`
            }
            i += 1;
        }
    }

    /// Harvest per-connection byte counters into the frame-I/O metrics
    /// and journal (one entry per direction per turn, when nonzero).
    fn collect_io(&mut self) {
        if self.obs.is_none() {
            return;
        }
        let mut bi = 0u64;
        let mut bo = 0u64;
        for slot in &mut self.slots {
            if let Some(c) = &mut slot.conn {
                let (i, o) = c.take_io();
                bi += i;
                bo += o;
            }
        }
        for p in &mut self.pending {
            let (i, o) = p.conn.take_io();
            bi += i;
            bo += o;
        }
        for c in &mut self.ctrl_conns {
            let (i, o) = c.conn.take_io();
            bi += i;
            bo += o;
        }
        if bi == 0 && bo == 0 {
            return;
        }
        let ts = self.clock_start.elapsed().as_secs_f64();
        let fo = self.obs.as_ref().expect("checked above");
        if bi > 0 {
            fo.bytes_in.add(bi);
            fo.obs.journal.record(ts, EventKind::FrameBytes, -1, -1, 0, bi as f64);
        }
        if bo > 0 {
            fo.bytes_out.add(bo);
            fo.obs.journal.record(ts, EventKind::FrameBytes, -1, -1, 1, bo as f64);
        }
    }

    /// Accept every queued connection into the pending (pre-`Hello`)
    /// set. A stray connection that never sends anything (port scanner,
    /// health check) just times out there; it can neither tear the
    /// master down nor head-of-line-block honest workers.
    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, peer)) => {
                    if let Ok(conn) = Connection::new(stream) {
                        self.pending.push(PendingConn {
                            conn,
                            peer: peer.to_string(),
                            since: Instant::now(),
                            ready: true,
                        });
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => return,
            }
        }
    }

    /// Advance every pending handshake: admit completed `Hello`s, drop
    /// protocol violators and expired strays.
    fn process_pending(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.pending.len() {
            let mut admit: Option<usize> = None;
            let mut remove =
                now.duration_since(self.pending[i].since) > self.membership.hello_timeout;
            if remove {
                log_warn!(
                    "fleet master: rejecting {}: no Hello within {:?}",
                    self.pending[i].peer,
                    self.membership.hello_timeout
                );
            } else if self.pending[i].ready {
                self.pending[i].ready = false;
                let alive = self.pending[i].conn.fill();
                match self.pending[i].conn.try_next_frame() {
                    Ok(Some(Frame::Hello { worker_id })) => {
                        admit = Some(worker_id as usize);
                        remove = true;
                    }
                    Ok(Some(other)) => {
                        log_warn!(
                            "fleet master: rejecting {}: expected Hello, got {other:?}",
                            self.pending[i].peer
                        );
                        let conn = &mut self.pending[i].conn;
                        conn.send(&Frame::Error {
                            code: ERR_BAD_HANDSHAKE,
                            msg: "expected Hello as the first frame".to_string(),
                        });
                        conn.flush();
                        remove = true;
                    }
                    Ok(None) => {
                        if !alive || self.pending[i].conn.is_dead() {
                            remove = true;
                        }
                    }
                    // Version-compat gate: an old-wire peer gets a
                    // v2 farewell frame naming both versions before the
                    // close — a clear error on its side, never a panic
                    // or silent hangup on ours.
                    Err(WireError::BadVersion(v)) => {
                        log_warn!(
                            "fleet master: rejecting {}: wire version {v} \
                             (this master speaks v{WIRE_VERSION})",
                            self.pending[i].peer
                        );
                        let conn = &mut self.pending[i].conn;
                        conn.send(&Frame::Error {
                            code: ERR_BAD_VERSION,
                            msg: format!(
                                "unsupported wire version {v}: this master speaks \
                                 v{WIRE_VERSION}; upgrade the worker"
                            ),
                        });
                        conn.flush();
                        remove = true;
                    }
                    Err(e) => {
                        log_warn!(
                            "fleet master: rejecting {}: malformed handshake ({e})",
                            self.pending[i].peer
                        );
                        remove = true;
                    }
                }
            }
            if remove {
                let p = self.pending.swap_remove(i);
                if let Some(id) = admit {
                    self.admit_worker(id, p.conn, &p.peer);
                } else {
                    p.conn.shutdown();
                }
                continue; // swap_remove moved a new entry into `i`
            }
            i += 1;
        }
    }

    /// Admit a completed handshake into the roster: claim (or reclaim,
    /// or grow to) slot `id`. Frames the worker queued behind its
    /// `Hello` are absorbed immediately.
    fn admit_worker(&mut self, id: usize, conn: Connection, peer: &str) {
        let reject = |why: &str| {
            log_warn!("fleet master: rejecting {peer}: {why}");
        };
        if !self.started && id >= self.initial_n {
            reject(&format!("worker id {id} out of range (fleet of {})", self.initial_n));
            conn.shutdown();
            return;
        }
        let gap = id.saturating_sub(self.slots.len());
        if gap > self.phantom_budget {
            reject(&format!(
                "worker id {id} skips {gap} ids past current capacity {} \
                 (remaining gap budget {})",
                self.slots.len(),
                self.phantom_budget
            ));
            conn.shutdown();
            return;
        }
        if let Some(slot) = self.slots.get(id) {
            if slot.byzantine {
                reject(&format!("worker id {id} was retired as byzantine"));
                conn.shutdown();
                return;
            }
            if slot.live {
                reject(&format!("duplicate worker id {id}"));
                conn.shutdown();
                return;
            }
        }
        let now = Instant::now();
        self.phantom_budget -= gap; // the skipped ids become phantom slots
        while self.slots.len() <= id {
            self.slots.push(WorkerSlot::vacant(now));
        }
        let rejoin = self.slots[id].ever_joined;
        let slot = &mut self.slots[id];
        slot.conn = Some(conn);
        slot.live = true;
        slot.ever_joined = true;
        slot.stale = false;
        slot.last_seen = now;
        // A fresh connection has seen nothing: forget what the old one
        // was shipped so the gradient prereqs go out again. (The worker
        // may have kept its caches across a reconnect — re-shipping is
        // idempotent there, and a genuinely new process needs it all.)
        slot.sent_specs.clear();
        slot.sent_chunks.clear();
        slot.sent_params.clear();
        slot.grad_asm.clear();
        if self.started {
            self.staged.push(ClusterEvent::WorkerJoined { worker: id });
            if let Some(fo) = &self.obs {
                fo.joins.inc();
                fo.obs.journal.record(
                    self.clock_start.elapsed().as_secs_f64(),
                    EventKind::WorkerJoin,
                    -1,
                    -1,
                    id as i64,
                    if rejoin { 1.0 } else { 0.0 },
                );
            }
            log_info!(
                "fleet master: worker {id} {} the fleet (live {}/{})",
                if rejoin { "rejoined" } else { "joined" },
                self.live_workers(),
                self.slots.len()
            );
        }
        // Mid-round rejoin: re-send every Assign the worker still owes a
        // Result for. A rejoiner that answers before the open round's
        // μ-cutoff costs the run nothing instead of one straggler cut —
        // the per-round checksum log outlives retirement, so the
        // replayed Result verifies exactly like the original would
        // have. Timed-out rounds are past saving and skipped.
        if rejoin && self.started {
            let mut replayed = 0usize;
            for seq in 0..self.round_starts.len() {
                if id < self.assigned_log[seq].len()
                    && self.assigned_log[seq][id]
                    && self.finish_log[seq][id].is_none()
                    && !self.timeout_emitted[seq]
                {
                    let sent = if let Some(frame) =
                        self.grad_assign_log[seq].get(&id).cloned()
                    {
                        // gradient round: the prereqs (spec, partitions,
                        // the pinned param version) must land on the new
                        // connection before the assignment itself
                        let Frame::GradAssign { job, param_version, ref units, .. } =
                            frame
                        else {
                            unreachable!("grad_assign_log holds GradAssign frames only")
                        };
                        let needed = units_chunks(units);
                        self.ship_grad_prereqs(id, job, param_version, &needed)
                            && self.send_to(id, &frame)
                    } else {
                        let load = self.loads_log[seq][id];
                        let chunks =
                            vec![(seq + 1) as u32, id as u32, (load * 1e6) as u32];
                        let frame = Frame::Assign {
                            round: (seq + 1) as u32,
                            work_units: load,
                            chunks,
                        };
                        self.send_to(id, &frame)
                    };
                    if !sent {
                        self.retire(id, "assign replay write failed");
                        return;
                    }
                    replayed += 1;
                }
            }
            if replayed > 0 {
                log_info!(
                    "fleet master: replayed {replayed} open assignment(s) to rejoined worker {id}"
                );
            }
        }
        // a worker may queue heartbeats right behind its Hello; they are
        // already buffered, so no readiness event will re-announce them
        self.drain_slot_frames(id);
    }

    /// Drain the socket of slot `i` and absorb every complete frame;
    /// retires the worker when the connection is gone.
    fn read_slot(&mut self, i: usize) {
        let alive = match &mut self.slots[i].conn {
            Some(c) => c.fill(),
            None => return,
        };
        // drain buffered frames first: an EOF may trail a final Result
        self.drain_slot_frames(i);
        let dead = !alive || self.slots[i].conn.as_ref().is_some_and(|c| c.is_dead());
        if dead {
            self.retire(i, "connection lost");
        }
    }

    fn drain_slot_frames(&mut self, i: usize) {
        let at = Instant::now();
        loop {
            let frame = match &mut self.slots[i].conn {
                Some(c) => c.next_frame(),
                None => return, // retired mid-drain (byzantine)
            };
            match frame {
                Some(f) => self.absorb(i, f, at),
                None => return,
            }
        }
    }

    /// Flush queued outbound bytes (Assigns that exceeded the socket
    /// buffer); retires the worker on a fatal write error.
    fn flush_slot(&mut self, i: usize) {
        let ok = match &mut self.slots[i].conn {
            Some(c) => c.flush(),
            None => return,
        };
        if !ok {
            self.retire(i, "write failed");
        }
    }

    /// Process one inbound frame, translating results into staged
    /// [`ClusterEvent`]s.
    fn absorb(&mut self, worker: usize, frame: Frame, at: Instant) {
        if let Some(ch) = &self.chaos {
            // Scripted partition: the victim's inbound frames — results
            // and heartbeats alike — vanish for the window, before they
            // can refresh `last_seen`.
            if (self.round_starts.len() as u64)
                < ch.drop_until.get(worker).copied().unwrap_or(0)
            {
                return;
            }
        }
        {
            let slot = &mut self.slots[worker];
            slot.last_seen = at;
            // a live frame resurrects a stale-heartbeat false positive
            slot.stale = false;
        }
        match frame {
            Frame::Result { round: r, checksum, .. } => {
                if self.slots[worker].byzantine {
                    return; // nothing from a byzantine worker is trusted
                }
                let idx = r as usize;
                if idx == 0 || idx > self.round_starts.len() {
                    return;
                }
                let seq = idx - 1;
                if worker >= self.finish_log[seq].len() {
                    return; // joined after this submission was fanned out
                }
                if checksum != self.sum_log[seq][worker] {
                    // byzantine: the worker did not do the work it was
                    // assigned — never trust it again
                    log_warn!(
                        "fleet master: worker {worker} returned a bad checksum \
                         for round {r}; marking it byzantine"
                    );
                    self.slots[worker].byzantine = true;
                    self.retire(worker, "byzantine result");
                    return;
                }
                let rel = at
                    .checked_duration_since(self.round_starts[seq])
                    .map_or(0.0, |d| d.as_secs_f64())
                    .max(1e-9);
                if self.finish_log[seq][worker].is_none() {
                    self.finish_log[seq][worker] = Some(rel);
                    let (job, round) = self.seq_jobs[seq];
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round,
                        worker,
                        finish_s: rel,
                    });
                }
            }
            Frame::GradResult { job, round: r, param_version, off, total, data, .. } => {
                if self.slots[worker].byzantine {
                    return; // nothing from a byzantine worker is trusted
                }
                let idx = r as usize;
                if idx == 0 || idx > self.round_starts.len() {
                    return;
                }
                let seq = idx - 1;
                if worker >= self.finish_log[seq].len() {
                    return; // joined after this submission was fanned out
                }
                let (sjob, sround) = self.seq_jobs[seq];
                if sjob as u32 != job {
                    return; // job id does not match the answered submission
                }
                let key = (job, r);
                if off == 0 {
                    // a resend restarts the assembly (worker-side slices
                    // always begin at 0)
                    self.slots[worker].grad_asm.insert(key, TensorAssembly::new(total));
                }
                let Some(asm) = self.slots[worker].grad_asm.get_mut(&key) else {
                    return; // slice of an abandoned assembly
                };
                match asm.accept(off, &data) {
                    Ok(false) => return, // more slices coming
                    Ok(true) => {}
                    Err(_) => {
                        self.slots[worker].grad_asm.remove(&key);
                        return;
                    }
                }
                let asm =
                    self.slots[worker].grad_asm.remove(&key).expect("assembly completed");
                let payload = asm.take();
                let bytes = payload.len() as u64 * 4;
                // Store into the staged round entry; a `false` means the
                // round already folded (a μ-cut straggler reporting late)
                // or the version is stale — the payload is dropped, like
                // a late synthetic Result is ignored.
                let stored = {
                    let Some(dp) = self.dp.clone() else { return };
                    let mut d = dp.lock().expect("data plane lock poisoned");
                    let ok = d.store_payload(job, sround, worker, param_version, payload);
                    if ok {
                        d.add_grad_bytes(job, bytes);
                    }
                    ok
                };
                if stored {
                    if let Some(fo) = &mut self.obs {
                        fo.grad_bytes_counter(job).add(bytes);
                    }
                }
                // The worker completed its round either way: time the
                // arrival for the μ-rule (a dropped stale payload is a
                // data-plane concern, not a liveness one).
                let rel = at
                    .checked_duration_since(self.round_starts[seq])
                    .map_or(0.0, |d| d.as_secs_f64())
                    .max(1e-9);
                if self.finish_log[seq][worker].is_none() {
                    self.finish_log[seq][worker] = Some(rel);
                    self.staged.push(ClusterEvent::WorkerDone {
                        job: sjob,
                        round: sround,
                        worker,
                        finish_s: rel,
                    });
                }
            }
            _ => {}
        }
    }

    /// Permanently remove `worker` from the roster: close its socket,
    /// stage [`ClusterEvent::WorkerRetired`] plus
    /// [`ClusterEvent::WorkerDead`] for every submission it still owes.
    /// The slot id stays reserved and may be reclaimed by a fresh
    /// `Hello` (unless the worker was byzantine).
    fn retire(&mut self, worker: usize, why: &str) {
        let slot = &mut self.slots[worker];
        let was_live = slot.live;
        if let Some(c) = slot.conn.take() {
            c.shutdown();
        }
        slot.live = false;
        slot.stale = false;
        if was_live {
            if self.started {
                self.staged.push(ClusterEvent::WorkerRetired { worker });
                if let Some(fo) = &self.obs {
                    fo.retires.inc();
                    fo.obs.journal.record(
                        self.clock_start.elapsed().as_secs_f64(),
                        EventKind::WorkerRetire,
                        -1,
                        -1,
                        worker as i64,
                        0.0,
                    );
                }
                log_warn!("fleet master: retiring worker {worker} ({why})");
            }
            self.stage_owed_deaths(worker);
        }
    }

    /// Stage `WorkerDead` for every submission `worker` was assigned but
    /// never answered (once per submission).
    fn stage_owed_deaths(&mut self, worker: usize) {
        for seq in 0..self.round_starts.len() {
            if worker < self.assigned_log[seq].len()
                && self.assigned_log[seq][worker]
                && self.finish_log[seq][worker].is_none()
                && !self.dead_notified[seq][worker]
            {
                self.dead_notified[seq][worker] = true;
                let (job, round) = self.seq_jobs[seq];
                self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
            }
        }
    }

    /// Run the time-based checks: heartbeat staleness, the reap policy
    /// and per-submission hard caps.
    fn run_timers(&mut self) {
        self.drain_grad_flags();
        let now = Instant::now();
        for i in 0..self.slots.len() {
            if !self.slots[i].live {
                continue;
            }
            let gap = now.duration_since(self.slots[i].last_seen);
            if gap > self.membership.reap_after {
                self.retire(i, "heartbeats silent past the reap deadline");
            } else if gap > self.membership.heartbeat_timeout {
                // recoverable: skip new Assigns while stale, but stage no
                // WorkerDead (see `retire` for the permanent path)
                if !self.slots[i].stale {
                    if let Some(fo) = &self.obs {
                        fo.stale_marks.inc();
                        fo.obs.journal.record(
                            self.clock_start.elapsed().as_secs_f64(),
                            EventKind::HeartbeatStale,
                            -1,
                            -1,
                            i as i64,
                            gap.as_secs_f64(),
                        );
                    }
                }
                self.slots[i].stale = true;
            }
        }
        self.check_round_timeouts(now);
    }

    /// A submission still has *live* assigned workers missing. Slots
    /// whose only missing workers were already reported dead
    /// (`dead_notified`) count as settled: the scheduler got their
    /// `WorkerDead` and has either cut them or failed the job, so
    /// re-timing the submission would only pin the scan watermark and
    /// stage a spurious late timeout.
    fn unsettled(&self, seq: usize) -> bool {
        !self.timeout_emitted[seq]
            && self.finish_log[seq].iter().enumerate().any(|(w, f)| {
                f.is_none() && self.assigned_log[seq][w] && !self.dead_notified[seq][w]
            })
    }

    /// Stage `RoundTimeout` for submissions past the hard cap that are
    /// still unsettled.
    fn check_round_timeouts(&mut self, now: Instant) {
        // advance the watermark past settled submissions
        while self.timeout_scan_from < self.round_starts.len()
            && !self.unsettled(self.timeout_scan_from)
        {
            self.timeout_scan_from += 1;
        }
        for seq in self.timeout_scan_from..self.round_starts.len() {
            if self.unsettled(seq)
                && now.duration_since(self.round_starts[seq]) > self.round_timeout
            {
                self.timeout_emitted[seq] = true;
                let (job, round) = self.seq_jobs[seq];
                self.staged.push(ClusterEvent::RoundTimeout { job, round });
            }
        }
    }

    /// The earliest instant a time-based check could matter: the
    /// caller's horizon, the next heartbeat-staleness or reap deadline,
    /// the first unsettled submission's hard cap, or a pending
    /// handshake's expiry. `None` means no deadline at all — the
    /// reactor may block on readiness alone.
    fn next_wakeup(&self, horizon: Option<Instant>) -> Option<Instant> {
        fn earlier(a: Option<Instant>, b: Instant) -> Option<Instant> {
            Some(match a {
                Some(x) if x <= b => x,
                _ => b,
            })
        }
        let mut next = horizon;
        for slot in &self.slots {
            if !slot.live {
                continue;
            }
            next = earlier(next, slot.last_seen + self.membership.reap_after);
            if !slot.stale {
                next = earlier(next, slot.last_seen + self.membership.heartbeat_timeout);
            }
        }
        for p in &self.pending {
            next = earlier(next, p.since + self.membership.hello_timeout);
        }
        for seq in self.timeout_scan_from..self.round_starts.len() {
            if self.unsettled(seq) {
                // submissions start in order: the first unsettled one
                // owns the earliest hard cap
                next = earlier(next, self.round_starts[seq] + self.round_timeout);
                break;
            }
        }
        next
    }

    /// Drain late results until the trace matrix is complete (or
    /// `flush_timeout` passes), then return the recorded trace. Cut
    /// stragglers keep computing and report late, so a healthy fleet
    /// always completes its matrix. Entries of workers that retired are
    /// synthesized past the round's `(1+μ)` cutoff (`mu` is the session's
    /// μ), so replaying the trace cuts them exactly like the live run
    /// did; rows recorded before a capacity growth are padded the same
    /// way.
    pub fn finish_trace(&mut self, flush_timeout: Duration, mu: f64) -> RunTrace {
        let deadline = Instant::now() + flush_timeout;
        // only wait for slots a live worker could still fill — entries of
        // retired workers and rounds never assigned to a worker are
        // synthesized below, and waiting on them would stall every
        // post-failure run for the whole timeout
        let incomplete = |fleet: &Self| {
            fleet.finish_log.iter().zip(&fleet.assigned_log).any(|(row, assigned)| {
                row.iter().enumerate().any(|(w, f)| {
                    f.is_none()
                        && assigned[w]
                        && fleet.slots[w].live
                        && !fleet.slots[w].byzantine
                })
            })
        };
        while incomplete(self) && Instant::now() < deadline {
            let wake = self.next_wakeup(Some(deadline)).unwrap_or(deadline);
            self.reactor_turn(Some(wake.saturating_duration_since(Instant::now())));
            self.process_pending();
            self.run_timers();
            // nobody polls after a run: translated events are not wanted
            self.staged.clear();
        }
        let cap = self.slots.len();
        let mut trace = RunTrace::new(cap);
        for (loads, finish) in self.loads_log.iter().zip(&self.finish_log) {
            let worst =
                finish.iter().flatten().cloned().fold(0.0f64, f64::max).max(1e-3);
            // strictly beyond any μ-cutoff: κ ≤ worst ⇒ (1+μ)·2·worst > (1+μ)·κ
            let missing_fill = (1.0 + mu.max(0.0)) * worst * 2.0;
            // traces replay through load-driven samplers: clamp UNPLACED
            // markers to a plain zero load
            let mut lrow: Vec<f64> = loads.iter().map(|&l| l.max(0.0)).collect();
            lrow.resize(cap, 0.0);
            let mut frow: Vec<f64> =
                finish.iter().map(|f| f.unwrap_or(missing_fill)).collect();
            frow.resize(cap, missing_fill);
            trace.push(lrow, frow, None);
        }
        trace
    }

    /// Send `Shutdown` to every worker, briefly flush, and close all
    /// sockets (idempotent). Closing unconditionally matters: a worker
    /// that was stale-paused is still blocked in its read loop and must
    /// see EOF to exit, or joining it hangs.
    pub fn shutdown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        for slot in &mut self.slots {
            if let Some(c) = &mut slot.conn {
                c.send(&Frame::Shutdown);
            }
        }
        // bounded best-effort flush of sockets with queued output
        let deadline = Instant::now() + Duration::from_millis(250);
        loop {
            self.pollfds.clear();
            self.owners.clear();
            for (i, slot) in self.slots.iter().enumerate() {
                if let Some(c) = &slot.conn {
                    if c.wants_write() && !c.is_dead() {
                        self.pollfds.push(PollFd::new(c.fd(), POLLOUT));
                        self.owners.push(Owner::Slot(i));
                    }
                }
            }
            let now = Instant::now();
            if self.pollfds.is_empty() || now >= deadline {
                break;
            }
            if poll_fds(&mut self.pollfds, Some(deadline - now)).is_err() {
                break;
            }
            let owners = std::mem::take(&mut self.owners);
            for owner in &owners {
                if let Owner::Slot(i) = owner {
                    if let Some(c) = &mut self.slots[*i].conn {
                        c.flush();
                    }
                }
            }
            self.owners = owners;
        }
        for slot in &mut self.slots {
            if let Some(c) = slot.conn.take() {
                c.shutdown();
            }
        }
        for p in self.pending.drain(..) {
            p.conn.shutdown();
        }
        self.listener = None;
        self.scrapes.clear(); // dropping the streams closes them
        self.metrics_listener = None;
        for c in self.ctrl_conns.drain(..) {
            c.conn.shutdown();
        }
        self.jobs_listener = None;
        if let Some(ctrl) = &self.control {
            ctrl.lock().expect("control queue lock poisoned").closed = true;
        }
    }
}

impl Drop for FleetCluster {
    fn drop(&mut self) {
        self.shutdown(); // closes every socket → workers see EOF and exit
    }
}

impl EventCluster for FleetCluster {
    fn n(&self) -> usize {
        self.slots.len()
    }

    fn now_s(&self) -> f64 {
        self.clock_start.elapsed().as_secs_f64()
    }

    /// Assign `(job, round)` to every usable worker under the next wire
    /// sequence number. Workers already retired or stale-paused (or
    /// whose socket write fails) get an immediate staged
    /// [`ClusterEvent::WorkerDead`] — the μ-rule will cut them; the
    /// wait-out policy may still fail the job if it needs them.
    ///
    /// Zero-load workers are assigned like everyone else (one tiny
    /// frame, a `base_s` minitask): a `0.0` load is *not* proof the
    /// worker is outside the job — M-SGC legitimately assigns noop
    /// rounds (load 0) to placed workers and still expects their
    /// completion times. Workers the job genuinely does not place are
    /// marked with [`UNPLACED`](crate::cluster::UNPLACED) (any negative
    /// load) by the scheduler and skipped entirely: no frame, no
    /// `assigned_log` entry, no owed `WorkerDead` — wide spare pools
    /// cost no per-round traffic.
    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
        assert_eq!(loads.len(), self.slots.len(), "loads/fleet size mismatch");
        assert!(!self.shut_down, "submit on a shut-down fleet");
        let cap = self.slots.len();
        let seq = self.round_starts.len() + 1;
        // Scripted shrinks/partitions fire before the fan-out, so a
        // shrink victim is already retired (→ immediate `WorkerDead`
        // below) and a partition victim's frames start dropping with
        // this submission.
        self.apply_chaos(seq as u64);
        self.round_starts.push(Instant::now());
        self.seq_jobs.push((job, round));
        self.loads_log.push(loads.to_vec());
        self.finish_log.push(vec![None; cap]);
        self.assigned_log.push(vec![false; cap]);
        self.dead_notified.push(vec![false; cap]);
        self.timeout_emitted.push(false);
        self.sum_log.push(vec![0; cap]);
        self.grad_assign_log.push(HashMap::new());
        // A staged data-plane entry switches this submission's fan-out
        // to the gradient protocol for every worker it gives real work;
        // workers the entry leaves unit-less (noop rounds) still get the
        // synthetic Assign so the μ-rule sees their completion times.
        let grad_ctx: Option<(u32, Vec<Vec<GradUnit>>)> = self.dp.as_ref().and_then(|dp| {
            let d = dp.lock().expect("data plane lock poisoned");
            d.round(job as u32, round).map(|e| (e.param_version, e.wire.clone()))
        });
        for worker in 0..cap {
            if loads[worker] < 0.0 {
                // UNPLACED: outside this submission — owes nothing
                continue;
            }
            let mut lost = !self.slots[worker].usable();
            let grad_units = grad_ctx.as_ref().and_then(|(v, wire)| {
                wire.get(worker).filter(|u| !u.is_empty()).map(|u| (*v, u.clone()))
            });
            if !lost {
                let sent = if let Some((version, units)) = grad_units {
                    // real-gradient fan-out: prereqs (spec, missing
                    // partitions, the pinned param broadcast) ride the
                    // same in-order stream ahead of the assignment
                    let needed = units_chunks(&units);
                    let frame = Frame::GradAssign {
                        job: job as u32,
                        round: seq as u32,
                        param_version: version,
                        work_units: loads[worker],
                        units,
                    };
                    let ok = self.ship_grad_prereqs(worker, job as u32, version, &needed)
                        && self.send_to(worker, &frame);
                    if ok {
                        self.grad_assign_log.last_mut().unwrap().insert(worker, frame);
                    }
                    ok
                } else {
                    // The metadata protocol ships no real chunk ids; a
                    // synthetic (seq, worker, quantized load) triplet
                    // keeps the byzantine check meaningful — every
                    // Result must echo the checksum of *its own*
                    // assignment, so a worker replaying another round's
                    // (or worker's) answer, or skipping the work, is
                    // still caught. Jobs on the gradient data plane ship
                    // real partitions above instead.
                    let chunks =
                        vec![seq as u32, worker as u32, (loads[worker] * 1e6) as u32];
                    self.sum_log.last_mut().unwrap()[worker] = chunk_checksum(&chunks);
                    let frame = Frame::Assign {
                        round: seq as u32,
                        work_units: loads[worker],
                        chunks,
                    };
                    self.send_to(worker, &frame)
                };
                if sent {
                    self.assigned_log.last_mut().unwrap()[worker] = true;
                } else {
                    self.retire(worker, "assign write failed");
                    lost = true;
                }
            }
            if lost {
                let notified = self.dead_notified.last_mut().unwrap();
                if !notified[worker] {
                    notified[worker] = true;
                    self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
                }
            }
        }
    }

    /// Drain queued arrivals; if none are ready, sleep in one `poll(2)`
    /// until the first socket readiness or the earliest deadline (the
    /// caller's horizon, a heartbeat reap, a round's hard cap) — no
    /// fixed slices: an idle fleet wakes within a millisecond of
    /// `until_s`, and an arrival wakes it immediately. Wall time keeps
    /// flowing regardless of `until_s`; the horizon is purely a sleep
    /// bound.
    fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
        assert!(!until_s.is_nan(), "poll horizon must not be NaN");
        self.delivered.clear();
        let horizon = if until_s.is_finite() {
            let rel = (until_s - self.now_s()).max(0.0);
            Some(Instant::now() + Duration::from_secs_f64(rel))
        } else {
            None
        };
        loop {
            let timeout = if self.staged.is_empty() {
                self.next_wakeup(horizon)
                    .map(|dl| dl.saturating_duration_since(Instant::now()))
            } else {
                Some(Duration::ZERO) // events ready: sweep sockets, no sleep
            };
            // Degenerate state: nothing to watch and nothing scheduled —
            // no wakeup can ever occur. Return the empty batch so the
            // caller's liveness checks can fail the run loudly.
            let nothing_watched = !self.joins_open()
                && self.pending.is_empty()
                && self.slots.iter().all(|s| s.conn.is_none())
                && self.metrics_listener.is_none()
                && self.scrapes.is_empty()
                && self.jobs_listener.is_none()
                && self.ctrl_conns.is_empty();
            if timeout.is_none() && nothing_watched {
                break;
            }
            // Wake-slop: how far past its computed deadline a sleeping
            // turn actually woke. Only turns that ran to their deadline
            // count (an early socket wake is not slop).
            let slept = match timeout {
                Some(d) if !d.is_zero() && self.obs.is_some() => Some((Instant::now(), d)),
                _ => None,
            };
            self.reactor_turn(timeout);
            if let Some((t0, d)) = slept {
                let elapsed = t0.elapsed();
                if elapsed >= d {
                    let slop = (elapsed - d).as_secs_f64();
                    let fo = self.obs.as_ref().expect("slept implies obs");
                    fo.wake_slop.record(slop);
                    if slop > 0.005 {
                        fo.obs.journal.record(
                            self.clock_start.elapsed().as_secs_f64(),
                            EventKind::WakeSlop,
                            -1,
                            -1,
                            -1,
                            slop,
                        );
                    }
                }
            }
            self.process_pending();
            self.run_timers();
            if !self.staged.is_empty() {
                break;
            }
            // A queued submission is as wake-worthy as a cluster event:
            // return control so the serving loop can run admission.
            if self
                .control
                .as_ref()
                .is_some_and(|c| !c.lock().expect("control queue lock poisoned").incoming.is_empty())
            {
                break;
            }
            match horizon {
                Some(h) if Instant::now() >= h => break,
                _ => {}
            }
        }
        std::mem::swap(&mut self.delivered, &mut self.staged);
        self.staged.clear();
        &self.delivered
    }

    fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
        None // a real fleet has no ground truth
    }
}

/// The result of a fleet run: the protocol report plus the recorded
/// wall-clock delay trace (replayable via
/// [`RunTrace::replay`](crate::cluster::RunTrace::replay)).
pub struct FleetRun {
    /// The session's protocol report.
    pub report: RunReport,
    /// The recorded wall-clock delay matrix.
    pub trace: RunTrace,
}

/// Drive one session over a fleet with streaming arrivals and the
/// wall-clock μ-rule, collecting the delay trace along the way. This is
/// a single-job [`JobScheduler`](crate::sched::JobScheduler) run —
/// `sgc serve` admits several jobs onto the same fleet instead.
pub fn drive_fleet(
    scheme_cfg: &SchemeConfig,
    cfg: &SessionConfig,
    fleet: &mut FleetCluster,
) -> crate::Result<FleetRun> {
    // The submission log (and hence the trace) is per-fleet: a reused
    // fleet would interleave two runs' rounds. Fail fast instead.
    anyhow::ensure!(
        fleet.round_starts.is_empty(),
        "FleetCluster is single-use: this fleet already executed {} submissions; \
         spawn a fresh fleet per run",
        fleet.round_starts.len()
    );
    let report = crate::sched::drive_events(scheme_cfg, cfg, fleet)?;
    let mut trace = fleet.finish_trace(Duration::from_secs(10), cfg.mu);
    // A real fleet has no ground-truth straggler states; record the
    // μ-rule detections instead so the trace's pattern feeds
    // `SimCluster::from_trace` like a simulator trace does.
    for (tr, row) in trace.rounds.iter_mut().zip(&report.detected_pattern.rows) {
        tr.state = Some(row.clone());
    }
    Ok(FleetRun { report, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::LoopbackFleet;

    /// The reactor's `poll` horizon is exact: an idle fleet sleeps to
    /// the requested instant, not to the end of a 100 ms slice — and
    /// never wakes early. (The old thread-per-connection master quantized
    /// this to its fixed sleep granularity.)
    #[test]
    fn poll_horizon_is_exact_on_an_idle_fleet() {
        let mut fleet = LoopbackFleet::spawn(1, None).expect("spawn");
        let start = fleet.cluster.now_s();
        let events = fleet.cluster.poll(start + 0.25);
        assert!(events.is_empty(), "no submissions: no events, got {events:?}");
        let woke = fleet.cluster.now_s();
        assert!(
            woke - start >= 0.25,
            "poll returned {:.4}s early",
            start + 0.25 - woke
        );
        // generous upper bound: the property under test is "never early
        // and not slice-quantized", not scheduler latency on a loaded
        // CI box
        assert!(
            woke - start < 1.0,
            "poll overshot the horizon by {:.4}s",
            woke - start - 0.25
        );
        fleet.shutdown().expect("shutdown");
    }

    /// `RoundTimeout` fires only after the configured cap — never early
    /// because of sleep-slice quantization — and promptly after it.
    #[test]
    fn round_timeout_is_not_quantized_early() {
        // worker busy for ~2s per task; the hard cap is 0.4s
        let mut fleet = LoopbackFleet::spawn_with(1, |id, addr| {
            let mut cfg =
                crate::fleet::WorkerConfig::loopback(id, addr.to_string(), None);
            cfg.base_s = 2.0;
            cfg
        })
        .expect("spawn");
        fleet.cluster.set_round_timeout(Duration::from_millis(400));
        fleet.cluster.submit(0, 1, &[0.0]);
        let submitted = fleet.cluster.now_s();
        let timeout_at = loop {
            let now = fleet.cluster.now_s();
            assert!(now - submitted < 2.0, "round timeout never fired");
            let hit = fleet
                .cluster
                .poll(now + 0.05)
                .iter()
                .any(|e| matches!(e, ClusterEvent::RoundTimeout { job: 0, round: 1 }));
            if hit {
                break fleet.cluster.now_s();
            }
        };
        let elapsed = timeout_at - submitted;
        assert!(elapsed >= 0.4, "RoundTimeout fired {:.4}s early", 0.4 - elapsed);
        // loose upper bound for loaded CI runners; the guard above
        // already failed the test by 2.0s if the timer never fired
        assert!(elapsed < 1.4, "RoundTimeout fired {:.4}s late", elapsed - 0.4);
        // do not join the worker: it is mid-minitask; dropping the fleet
        // closes the sockets and the thread exits on its own
    }

    /// A worker that sends `Hello` after startup is admitted and
    /// announced; capacity grows to cover its id.
    #[test]
    fn late_join_is_admitted_and_announced() {
        let mut fleet = LoopbackFleet::spawn(2, None).expect("spawn");
        assert_eq!(EventCluster::n(&fleet.cluster), 2);
        fleet.join_worker(crate::fleet::WorkerConfig::loopback(
            2,
            fleet.cluster.addr().to_string(),
            None,
        ));
        let deadline = fleet.cluster.now_s() + 5.0;
        let mut joined = false;
        while !joined {
            let now = fleet.cluster.now_s();
            assert!(now < deadline, "late join never announced");
            joined = fleet
                .cluster
                .poll(now + 0.05)
                .iter()
                .any(|e| matches!(e, ClusterEvent::WorkerJoined { worker: 2 }));
        }
        assert_eq!(EventCluster::n(&fleet.cluster), 3);
        assert_eq!(fleet.cluster.live_workers(), 3);
        fleet.shutdown().expect("shutdown");
    }

    /// A worker whose socket drops is retired (with a `WorkerRetired`
    /// event) and owes `WorkerDead` for its open submissions.
    #[test]
    fn dropped_worker_is_retired() {
        let mut fleet = LoopbackFleet::spawn_with(2, |id, addr| {
            let mut cfg =
                crate::fleet::WorkerConfig::loopback(id, addr.to_string(), None);
            if id == 1 {
                cfg.fail_after_rounds = Some(1);
            }
            cfg
        })
        .expect("spawn");
        fleet.cluster.submit(0, 1, &[0.05, 0.05]);
        // worker 1 serves round 1 then crashes; wait for the retirement
        let deadline = fleet.cluster.now_s() + 5.0;
        let mut retired = false;
        while !retired {
            let now = fleet.cluster.now_s();
            assert!(now < deadline, "worker death never surfaced");
            retired = fleet
                .cluster
                .poll(now + 0.05)
                .iter()
                .any(|e| matches!(e, ClusterEvent::WorkerRetired { worker: 1 }));
        }
        assert_eq!(fleet.cluster.live_workers(), 1);
        // round 2: the retired worker is reported dead immediately
        fleet.cluster.submit(0, 2, &[0.05, 0.05]);
        let now = fleet.cluster.now_s();
        let events = fleet.cluster.poll(now);
        assert!(
            events
                .iter()
                .any(|e| matches!(e, ClusterEvent::WorkerDead { round: 2, worker: 1, .. })),
            "{events:?}"
        );
        // drain worker 0's round-2 result so it is idle before Shutdown
        let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
        fleet.shutdown().expect("shutdown");
    }

    /// Joins can be disabled after startup via the membership policy.
    #[test]
    fn closed_join_window_rejects_late_hellos() {
        let mut fleet = LoopbackFleet::spawn(1, None).expect("spawn");
        fleet.cluster.set_membership(MembershipConfig {
            join_window: Some(Duration::ZERO),
            ..MembershipConfig::default()
        });
        let addr = fleet.cluster.addr().to_string();
        let joiner = std::thread::spawn(move || {
            crate::fleet::run_worker(crate::fleet::WorkerConfig::loopback(1, addr, None))
        });
        // give the joiner time to connect, then poll: it must NOT appear
        let start = fleet.cluster.now_s();
        while fleet.cluster.now_s() - start < 0.3 {
            let now = fleet.cluster.now_s();
            let saw_join = fleet
                .cluster
                .poll(now + 0.05)
                .iter()
                .any(|e| matches!(e, ClusterEvent::WorkerJoined { .. }));
            assert!(!saw_join, "join window closed, yet a worker joined");
        }
        assert_eq!(EventCluster::n(&fleet.cluster), 1);
        // shutting the fleet down severs the never-accepted connection;
        // the rejected joiner then errors out (no assignment ever came)
        fleet.shutdown().expect("shutdown");
        assert!(joiner.join().expect("joiner thread").is_err());
    }
}
