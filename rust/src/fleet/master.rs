//! Master side of the fleet: accept worker connections and expose the
//! arrival stream as an [`EventCluster`] — the wall-clock backend behind
//! the multi-job [`JobScheduler`](crate::sched::JobScheduler).
//!
//! Unlike the simulator — whose clock only moves when `poll` advances it
//! — the fleet's clock is real: [`FleetCluster::poll`] drains the
//! per-connection reader threads' arrival channel, stamps each `Result`
//! frame with the master-side elapsed time of its submission, and sleeps
//! at most until the caller's horizon (the scheduler's next μ-cutoff).
//! The μ-rule itself stays in the sessions: the scheduler pumps
//! [`try_close_round`](crate::session::SgcSession::try_close_round)
//! with the wall clock, so a straggler that would take 10× the round
//! time costs the master nothing beyond the `(1+μ)·κ` cutoff — exactly
//! like the paper's Lambda master. Multiple jobs multiplex over one
//! fleet by sequence number: each submission gets the next wire-level
//! round id, and the master maps arrivals back to the owning
//! `(job, round)`.
//!
//! **Failure semantics.** Workers heartbeat between results. A worker
//! whose socket drops (or that returns a byzantine result) is reported
//! as [`ClusterEvent::WorkerDead`] for every submission it still owes;
//! the μ-rule cuts it like any straggler, and a run only errors when the
//! wait-out policy *needs* a dead worker (the pattern cannot conform
//! without it) — at that point no amount of waiting can help. Stale
//! heartbeats are *recoverable* (a fresh frame clears them), so they
//! pause new assignments but are never reported as deaths; a stall that
//! never recovers is bounded by the hard per-round cap, which emits
//! [`ClusterEvent::RoundTimeout`] once per submission.

use super::wire::{read_frame, write_frame, Frame};
use super::worker::chunk_checksum;
use crate::cluster::{ClusterEvent, EventCluster, JobId, RunTrace};
use crate::coding::SchemeConfig;
use crate::coordinator::metrics::RunReport;
use crate::session::SessionConfig;
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a connection reader observed.
enum Event {
    Frame { worker: usize, frame: Frame, at: Instant },
    Gone { worker: usize },
}

/// One worker's connection (write half; reads happen on a side thread).
struct Conn {
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
}

/// The fleet master's cluster handle: `n` connected workers plus the
/// arrival stream, implementing [`EventCluster`]. Blocking callers wrap
/// it in [`SyncAdapter`](crate::cluster::SyncAdapter); fallible
/// streaming runs go through [`drive_fleet`] or a
/// [`JobScheduler`](crate::sched::JobScheduler).
pub struct FleetCluster {
    n: usize,
    conns: Vec<Conn>,
    events: Receiver<Event>,
    last_seen: Vec<Instant>,
    /// Worker is currently considered unusable. Set by a dropped socket
    /// (`gone`), a bad checksum (`byzantine`), or stale heartbeats — the
    /// last is *recoverable*: a fresh frame from a non-gone,
    /// non-byzantine worker clears it (a transient stall on a loaded box
    /// must not permanently evict a healthy worker).
    dead: Vec<bool>,
    /// Socket-level death (connection dropped / write failed): permanent.
    gone: Vec<bool>,
    /// Worker returned a result that fails checksum verification:
    /// permanent — nothing it sends is trusted again.
    byzantine: Vec<bool>,
    /// Stale-heartbeat threshold.
    heartbeat_timeout: Duration,
    /// Hard cap on one submission's wall-clock time — a worker that
    /// heartbeats but never returns its result would otherwise livelock
    /// a wait-out that needs it.
    round_timeout: Duration,
    /// The fleet's time origin (`now_s` axis).
    clock_start: Instant,
    /// Wall-clock start per submission (index = wire round id - 1).
    round_starts: Vec<Instant>,
    /// Owning `(job, round)` per submission — the wire protocol carries
    /// only the sequence number; this is the multiplexing map back.
    seq_jobs: Vec<(JobId, u64)>,
    /// Trace under construction: every arrival lands here, including
    /// results for rounds the μ-rule already closed.
    finish_log: Vec<Vec<Option<f64>>>,
    loads_log: Vec<Vec<f64>>,
    /// Which workers actually received each submission's `Assign` (a
    /// worker dead at assign time is skipped and can never fill that
    /// round's slot, even if its `dead` flag later clears).
    assigned_log: Vec<Vec<bool>>,
    /// Expected `Result` checksum per submission per worker; a
    /// mismatching result is byzantine.
    sum_log: Vec<Vec<u64>>,
    /// `WorkerDead` already emitted for (submission, worker).
    dead_notified: Vec<Vec<bool>>,
    /// `RoundTimeout` already emitted per submission.
    timeout_emitted: Vec<bool>,
    /// First submission that might still owe a timeout check.
    timeout_scan_from: usize,
    /// Events translated but not yet handed out by `poll`.
    staged: Vec<ClusterEvent>,
    /// The batch the last `poll` returned (swap-recycled with `staged`).
    delivered: Vec<ClusterEvent>,
    shut_down: bool,
}

impl FleetCluster {
    /// Bind `addr` and wait for `n` workers to connect and claim
    /// distinct slots via `Hello`.
    pub fn listen(addr: &str, n: usize, accept_timeout: Duration) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("fleet master: bind {addr}: {e}"))?;
        Self::accept_on(listener, n, accept_timeout)
    }

    /// Bind an ephemeral localhost port, hand the bound address to
    /// `spawn_workers` (which starts the workers pointing at it), then
    /// accept all `n`. See [`LoopbackFleet`](super::LoopbackFleet) for
    /// the packaged version.
    pub fn listen_ephemeral(
        n: usize,
        accept_timeout: Duration,
        spawn_workers: impl FnOnce(&str),
    ) -> crate::Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        spawn_workers(&addr);
        Self::accept_on(listener, n, accept_timeout)
    }

    fn accept_on(
        listener: TcpListener,
        n: usize,
        accept_timeout: Duration,
    ) -> crate::Result<Self> {
        anyhow::ensure!(n > 0, "fleet needs at least one worker");
        let deadline = Instant::now() + accept_timeout;
        // Keep the handshake BufReader: a worker may already have queued
        // heartbeats behind its Hello, and any byte buffered here must
        // reach the reader thread or the wire stream desyncs.
        let mut slots: Vec<Option<(TcpStream, BufReader<TcpStream>)>> =
            (0..n).map(|_| None).collect();
        let mut connected = 0usize;
        listener.set_nonblocking(true)?;
        // Handshakes run on side threads: a stray connection that sends
        // nothing (port scanner, health check) must neither tear the
        // master down nor head-of-line-block honest workers.
        let (htx, hrx) = channel::<(String, crate::Result<HelloOutcome>)>();
        while connected < n {
            deadline.checked_duration_since(Instant::now()).ok_or_else(|| {
                anyhow::anyhow!("fleet master: only {connected}/{n} workers connected")
            })?;
            match listener.accept() {
                Ok((stream, peer)) => {
                    let htx = htx.clone();
                    std::thread::Builder::new()
                        .name("sgc-fleet-hello".into())
                        .spawn(move || {
                            let _ = htx.send((peer.to_string(), hello_handshake(stream)));
                        })
                        .expect("spawn handshake thread");
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => anyhow::bail!("fleet master: accept: {e}"),
            }
            while let Ok((peer, outcome)) = hrx.try_recv() {
                match outcome {
                    Ok((id, stream, reader)) if id < n && slots[id].is_none() => {
                        slots[id] = Some((stream, reader));
                        connected += 1;
                    }
                    Ok((id, _, _)) => {
                        eprintln!(
                            "fleet master: rejecting {peer}: bad or duplicate \
                             worker id {id} (fleet of {n})"
                        );
                    }
                    Err(e) => eprintln!("fleet master: rejecting {peer}: {e}"),
                }
            }
        }
        let (tx, rx) = channel();
        let conns = slots
            .into_iter()
            .enumerate()
            .map(|(worker, slot)| {
                let (stream, reader) = slot.expect("all slots filled");
                let handle = spawn_reader(worker, reader, tx.clone());
                Conn { stream, reader: Some(handle) }
            })
            .collect::<Vec<_>>();
        let now = Instant::now();
        Ok(FleetCluster {
            n,
            conns,
            events: rx,
            last_seen: vec![now; n],
            dead: vec![false; n],
            gone: vec![false; n],
            byzantine: vec![false; n],
            heartbeat_timeout: Duration::from_millis(1500),
            round_timeout: Duration::from_secs(60),
            clock_start: now,
            round_starts: Vec::new(),
            seq_jobs: Vec::new(),
            finish_log: Vec::new(),
            loads_log: Vec::new(),
            assigned_log: Vec::new(),
            sum_log: Vec::new(),
            dead_notified: Vec::new(),
            timeout_emitted: Vec::new(),
            timeout_scan_from: 0,
            staged: Vec::new(),
            delivered: Vec::new(),
            shut_down: false,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Submissions executed so far (wire-level rounds).
    pub fn submissions(&self) -> usize {
        self.round_starts.len()
    }

    /// Workers currently considered dead.
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.dead[i]).collect()
    }

    /// Raise (or lower) the hard per-round wall-clock cap. Needed when
    /// worker task durations are configured long (`sgc worker --base-s`).
    pub fn set_round_timeout(&mut self, timeout: Duration) {
        self.round_timeout = timeout;
    }

    /// Process one reader event, translating results into staged
    /// [`ClusterEvent`]s.
    fn absorb(&mut self, ev: Event) {
        match ev {
            Event::Frame { worker, frame, at } => {
                self.last_seen[worker] = at;
                // a live frame resurrects a stale-heartbeat false positive
                if self.dead[worker] && !self.gone[worker] && !self.byzantine[worker] {
                    self.dead[worker] = false;
                }
                if let Frame::Result { round: r, checksum, .. } = frame {
                    if self.byzantine[worker] {
                        return; // nothing from a byzantine worker is trusted
                    }
                    let idx = r as usize;
                    if idx >= 1 && idx <= self.round_starts.len() {
                        if checksum != self.sum_log[idx - 1][worker] {
                            // byzantine: the worker did not do the work it
                            // was assigned — never trust it again
                            eprintln!(
                                "fleet master: worker {worker} returned a bad \
                                 checksum for round {r}; marking it byzantine"
                            );
                            self.byzantine[worker] = true;
                            self.mark_dead(worker);
                            return;
                        }
                        let rel = at
                            .checked_duration_since(self.round_starts[idx - 1])
                            .map_or(0.0, |d| d.as_secs_f64())
                            .max(1e-9);
                        let slot = &mut self.finish_log[idx - 1][worker];
                        if slot.is_none() {
                            *slot = Some(rel);
                            let (job, round) = self.seq_jobs[idx - 1];
                            self.staged.push(ClusterEvent::WorkerDone {
                                job,
                                round,
                                worker,
                                finish_s: rel,
                            });
                        }
                    }
                }
            }
            Event::Gone { worker } => self.mark_gone(worker),
        }
    }

    /// Mark a worker *permanently* dead (gone socket / byzantine) and
    /// stage `WorkerDead` for every submission it still owes a result
    /// (once per submission). Stale-heartbeat deaths deliberately do NOT
    /// come through here: they are recoverable (any fresh frame clears
    /// them), so reporting them to the scheduler could fail a wait-out
    /// that a recovered worker was about to satisfy — those fall back to
    /// the round-timeout backstop instead.
    fn mark_dead(&mut self, worker: usize) {
        self.dead[worker] = true;
        for seq in 0..self.round_starts.len() {
            if self.assigned_log[seq][worker]
                && self.finish_log[seq][worker].is_none()
                && !self.dead_notified[seq][worker]
            {
                self.dead_notified[seq][worker] = true;
                let (job, round) = self.seq_jobs[seq];
                self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
            }
        }
    }

    /// Socket-level (permanent) death.
    fn mark_gone(&mut self, worker: usize) {
        self.gone[worker] = true;
        self.mark_dead(worker);
    }

    fn reap_stale_heartbeats(&mut self) {
        let now = Instant::now();
        for i in 0..self.n {
            if !self.dead[i]
                && now.duration_since(self.last_seen[i]) > self.heartbeat_timeout
            {
                // recoverable: skip new Assigns while stale, but stage no
                // WorkerDead (see `mark_dead`)
                self.dead[i] = true;
            }
        }
    }

    /// Stage `RoundTimeout` for submissions past the hard cap that still
    /// have *live* assigned workers missing. Slots whose only missing
    /// workers were already reported dead (`dead_notified`) count as
    /// settled: the scheduler got their `WorkerDead` and has either cut
    /// them or failed the job, so re-timing the submission would only
    /// pin the scan watermark and stage a spurious late timeout.
    fn check_round_timeouts(&mut self) {
        let now = Instant::now();
        let unsettled = |fleet: &Self, seq: usize| {
            !fleet.timeout_emitted[seq]
                && fleet.finish_log[seq].iter().enumerate().any(|(w, f)| {
                    f.is_none()
                        && fleet.assigned_log[seq][w]
                        && !fleet.dead_notified[seq][w]
                })
        };
        // advance the watermark past settled submissions
        while self.timeout_scan_from < self.round_starts.len()
            && !unsettled(self, self.timeout_scan_from)
        {
            self.timeout_scan_from += 1;
        }
        for seq in self.timeout_scan_from..self.round_starts.len() {
            if unsettled(self, seq)
                && now.duration_since(self.round_starts[seq]) > self.round_timeout
            {
                self.timeout_emitted[seq] = true;
                let (job, round) = self.seq_jobs[seq];
                self.staged.push(ClusterEvent::RoundTimeout { job, round });
            }
        }
    }

    /// Drain late results until the trace matrix is complete (or
    /// `flush_timeout` passes), then return the recorded trace. Cut
    /// stragglers keep computing and report late, so a healthy fleet
    /// always completes its matrix. Entries of workers that died are
    /// synthesized past the round's `(1+μ)` cutoff (`mu` is the session's
    /// μ), so replaying the trace cuts them exactly like the live run
    /// did.
    pub fn finish_trace(&mut self, flush_timeout: Duration, mu: f64) -> RunTrace {
        let deadline = Instant::now() + flush_timeout;
        // only wait for slots a live worker could still fill — entries of
        // gone/byzantine workers and rounds never assigned to a worker
        // are synthesized below, and waiting on them would stall every
        // post-failure run for the whole timeout
        let incomplete = |fleet: &Self| {
            fleet.finish_log.iter().zip(&fleet.assigned_log).any(|(row, assigned)| {
                row.iter().enumerate().any(|(w, f)| {
                    f.is_none() && assigned[w] && !fleet.gone[w] && !fleet.byzantine[w]
                })
            })
        };
        while incomplete(self) && Instant::now() < deadline {
            match self.events.recv_timeout(Duration::from_millis(25)) {
                Ok(ev) => self.absorb(ev),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            // nobody polls after a run: translated events are not wanted
            self.staged.clear();
        }
        let mut trace = RunTrace::new(self.n);
        for (loads, finish) in self.loads_log.iter().zip(&self.finish_log) {
            let worst =
                finish.iter().flatten().cloned().fold(0.0f64, f64::max).max(1e-3);
            // strictly beyond any μ-cutoff: κ ≤ worst ⇒ (1+μ)·2·worst > (1+μ)·κ
            let missing_fill = (1.0 + mu.max(0.0)) * worst * 2.0;
            let row: Vec<f64> = finish.iter().map(|f| f.unwrap_or(missing_fill)).collect();
            trace.push(loads.clone(), row, None);
        }
        trace
    }

    /// Send `Shutdown` to every worker and close all sockets
    /// (idempotent). Closing unconditionally matters: a worker that was
    /// *falsely* marked dead (stalled heartbeats) is still blocked in
    /// its read loop and must see EOF to exit, or joining it hangs.
    pub fn shutdown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        for conn in &mut self.conns {
            let _ = write_frame(&mut conn.stream, &Frame::Shutdown);
            let _ = conn.stream.shutdown(Shutdown::Both);
        }
    }
}

impl Drop for FleetCluster {
    fn drop(&mut self) {
        self.shutdown(); // closes every socket → reader threads unblock
        for conn in &mut self.conns {
            if let Some(h) = conn.reader.take() {
                let _ = h.join();
            }
        }
    }
}

impl EventCluster for FleetCluster {
    fn n(&self) -> usize {
        self.n
    }

    fn now_s(&self) -> f64 {
        self.clock_start.elapsed().as_secs_f64()
    }

    /// Assign `(job, round)` to every live worker under the next wire
    /// sequence number. Workers already dead (or whose socket write
    /// fails) get an immediate staged [`ClusterEvent::WorkerDead`] — the
    /// μ-rule will cut them; the wait-out policy may still fail the job
    /// if it needs them.
    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
        assert_eq!(loads.len(), self.n, "loads/fleet size mismatch");
        assert!(!self.shut_down, "submit on a shut-down fleet");
        let seq = self.round_starts.len() + 1;
        self.round_starts.push(Instant::now());
        self.seq_jobs.push((job, round));
        self.loads_log.push(loads.to_vec());
        self.finish_log.push(vec![None; self.n]);
        self.assigned_log.push(vec![false; self.n]);
        self.dead_notified.push(vec![false; self.n]);
        self.timeout_emitted.push(false);
        self.sum_log.push(vec![0; self.n]);
        for worker in 0..self.n {
            let mut lost = self.dead[worker];
            if !lost {
                // The metadata protocol ships no real chunk ids; a
                // synthetic (seq, worker, quantized load) triplet keeps
                // the byzantine check meaningful — every Result must
                // echo the checksum of *its own* assignment, so a worker
                // replaying another round's (or worker's) answer, or
                // skipping the work, is still caught. Real chunk shipping
                // returns with the real-compute fleet (ROADMAP).
                let chunks =
                    vec![seq as u32, worker as u32, (loads[worker] * 1e6) as u32];
                self.sum_log.last_mut().unwrap()[worker] = chunk_checksum(&chunks);
                let frame = Frame::Assign {
                    round: seq as u32,
                    work_units: loads[worker],
                    chunks,
                };
                if write_frame(&mut self.conns[worker].stream, &frame).is_ok() {
                    self.assigned_log.last_mut().unwrap()[worker] = true;
                } else {
                    self.mark_gone(worker);
                    lost = true;
                }
            }
            if lost {
                let notified = self.dead_notified.last_mut().unwrap();
                if !notified[worker] {
                    notified[worker] = true;
                    self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
                }
            }
        }
    }

    /// Drain queued arrivals; if none are ready, block until the first
    /// frame, the caller's horizon, or a short heartbeat pace — whichever
    /// comes first — then run the stale-heartbeat and round-timeout
    /// checks. Wall time keeps flowing regardless of `until_s`; the
    /// horizon is purely a sleep bound.
    fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
        assert!(!until_s.is_nan(), "poll horizon must not be NaN");
        self.delivered.clear();
        while let Ok(ev) = self.events.try_recv() {
            self.absorb(ev);
        }
        if self.staged.is_empty() {
            // Nothing ready: sleep towards the horizon, but wake at
            // heartbeat pace so liveness/timeout checks keep running
            // even on a silent fleet.
            let headroom = (until_s - self.now_s()).max(0.001);
            let wait = Duration::from_secs_f64(headroom.min(0.1));
            match self.events.recv_timeout(wait) {
                Ok(ev) => {
                    self.absorb(ev);
                    // take whatever queued up behind it
                    while let Ok(ev) = self.events.try_recv() {
                        self.absorb(ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                // All reader threads exited; their Gone events were
                // already absorbed, so every worker is marked dead and
                // the caller's dead-worker/timeout checks will fail the
                // run. Still honour the sleep bound — returning
                // instantly here would busy-spin the scheduler until the
                // μ-cutoff.
                Err(RecvTimeoutError::Disconnected) => std::thread::sleep(wait),
            }
        }
        self.reap_stale_heartbeats();
        self.check_round_timeouts();
        std::mem::swap(&mut self.delivered, &mut self.staged);
        self.staged.clear();
        &self.delivered
    }

    fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
        None // a real fleet has no ground truth
    }
}

/// The result of a fleet run: the protocol report plus the recorded
/// wall-clock delay trace (replayable via
/// [`RunTrace::replay`](crate::cluster::RunTrace::replay)).
pub struct FleetRun {
    pub report: RunReport,
    pub trace: RunTrace,
}

/// Drive one session over a fleet with streaming arrivals and the
/// wall-clock μ-rule, collecting the delay trace along the way. This is
/// a single-job [`JobScheduler`](crate::sched::JobScheduler) run —
/// `sgc serve` admits several jobs onto the same fleet instead.
pub fn drive_fleet(
    scheme_cfg: &SchemeConfig,
    cfg: &SessionConfig,
    fleet: &mut FleetCluster,
) -> crate::Result<FleetRun> {
    // The submission log (and hence the trace) is per-fleet: a reused
    // fleet would interleave two runs' rounds. Fail fast instead.
    anyhow::ensure!(
        fleet.round_starts.is_empty(),
        "FleetCluster is single-use: this fleet already executed {} submissions; \
         spawn a fresh fleet per run",
        fleet.round_starts.len()
    );
    let report = crate::sched::drive_events(scheme_cfg, cfg, fleet)?;
    let mut trace = fleet.finish_trace(Duration::from_secs(10), cfg.mu);
    // A real fleet has no ground-truth straggler states; record the
    // μ-rule detections instead so the trace's pattern feeds
    // `SimCluster::from_trace` like a simulator trace does.
    for (tr, row) in trace.rounds.iter_mut().zip(&report.detected_pattern.rows) {
        tr.state = Some(row.clone());
    }
    Ok(FleetRun { report, trace })
}

/// A completed handshake: claimed id, write half, and the (possibly
/// pre-filled) read half.
type HelloOutcome = (usize, TcpStream, BufReader<TcpStream>);

/// Complete one connection's `Hello` handshake (bounded at 5 s).
fn hello_handshake(stream: TcpStream) -> crate::Result<HelloOutcome> {
    // BSD-family accept() inherits the listener's O_NONBLOCK; this
    // connection must block (with a read timeout) for the handshake.
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    match read_frame(&mut reader) {
        Ok(Frame::Hello { worker_id }) => {
            stream.set_read_timeout(None)?;
            Ok((worker_id as usize, stream, reader))
        }
        Ok(other) => anyhow::bail!("expected Hello, got {other:?}"),
        Err(e) => anyhow::bail!("reading Hello: {e}"),
    }
}

fn spawn_reader(
    worker: usize,
    mut reader: BufReader<TcpStream>,
    tx: Sender<Event>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sgc-fleet-read-{worker}"))
        .spawn(move || {
            loop {
                match read_frame(&mut reader) {
                    Ok(frame) => {
                        let at = Instant::now();
                        if tx.send(Event::Frame { worker, frame, at }).is_err() {
                            break; // master dropped
                        }
                    }
                    // Closed and any other error both end the connection
                    Err(_) => {
                        let _ = tx.send(Event::Gone { worker });
                        break;
                    }
                }
            }
        })
        .expect("spawn fleet reader")
}
