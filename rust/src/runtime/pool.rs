//! Compute pool: PJRT executables pinned to dedicated threads.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so each compute thread owns
//! its own client + compiled executable; requests are dispatched
//! round-robin over channels. Simulated cluster workers block on the
//! returned handle, so many logical workers share a few physical compute
//! lanes — exactly like Lambda workers sharing the region's hardware.
//!
//! The real pool needs the `pjrt` feature (and with it the `xla` crate's
//! prebuilt `xla_extension`). Without it a stub [`ComputePool::new`]
//! returns a descriptive error, so the trainer and CLI still compile and
//! fail cleanly in environments without the PJRT toolchain.

use super::artifact::ModelDims;
use anyhow::Result;
#[cfg(not(feature = "pjrt"))]
use std::path::PathBuf;
#[cfg(not(feature = "pjrt"))]
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// A gradient request over one padded chunk.
pub struct GradRequest {
    /// 6 flattened parameter tensors (shared across workers in a round).
    pub params: Arc<Vec<Vec<f32>>>,
    /// Flattened input batch for the chunk.
    pub x: Vec<f32>,
    /// One-hot labels for the chunk.
    pub y: Vec<f32>,
    /// Per-example weights (zero pads masked out).
    pub wgt: Vec<f32>,
}

/// Result: `(loss_sum, grads, compute_seconds)`.
pub type GradResult = Result<(f32, Vec<Vec<f32>>, f64)>;

#[cfg(feature = "pjrt")]
mod real {
    use super::{GradRequest, GradResult, ModelDims, Result};
    use crate::runtime::artifact::GradExecutable;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc::{channel, Receiver, Sender};

    struct Job {
        req: GradRequest,
        reply: Sender<GradResult>,
    }

    /// Pool of PJRT compute lanes.
    pub struct ComputePool {
        txs: Vec<Sender<Job>>,
        next: AtomicUsize,
        dims: ModelDims,
        handles: Vec<std::thread::JoinHandle<()>>,
    }

    impl ComputePool {
        /// Spawn `lanes` compute threads, each compiling the artifact in
        /// `dir`.
        pub fn new(dir: PathBuf, lanes: usize) -> Result<Self> {
            assert!(lanes > 0);
            // Probe once on the caller thread for early, readable errors
            // and to learn the dims.
            let dims = GradExecutable::load(&dir)?.dims;
            let mut txs = Vec::with_capacity(lanes);
            let mut handles = Vec::with_capacity(lanes);
            for lane in 0..lanes {
                let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
                let dir = dir.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("sgc-compute-{lane}"))
                    .spawn(move || {
                        let exe = match GradExecutable::load(&dir) {
                            Ok(e) => e,
                            Err(e) => {
                                // Fail every request with a clone of the
                                // error.
                                for job in rx {
                                    let _ = job.reply.send(Err(anyhow::anyhow!(
                                        "lane failed to load: {e:#}"
                                    )));
                                }
                                return;
                            }
                        };
                        for job in rx {
                            let t0 = std::time::Instant::now();
                            let out = exe
                                .grad_chunk(
                                    &job.req.params,
                                    &job.req.x,
                                    &job.req.y,
                                    &job.req.wgt,
                                )
                                .map(|(loss, grads)| {
                                    (loss, grads, t0.elapsed().as_secs_f64())
                                });
                            let _ = job.reply.send(out);
                        }
                    })
                    .expect("spawn compute lane");
                txs.push(tx);
                handles.push(handle);
            }
            Ok(ComputePool { txs, next: AtomicUsize::new(0), dims, handles })
        }

        /// Shapes the pool's program was lowered for.
        pub fn dims(&self) -> ModelDims {
            self.dims
        }

        /// Submit a request; returns a receiver for the result.
        pub fn submit(&self, req: GradRequest) -> Receiver<GradResult> {
            let (reply, rx) = channel();
            let lane = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
            self.txs[lane].send(Job { req, reply }).expect("compute lane alive");
            rx
        }

        /// Convenience: submit and block.
        pub fn grad_chunk_blocking(&self, req: GradRequest) -> GradResult {
            self.submit(req).recv().expect("compute lane replied")
        }
    }

    impl Drop for ComputePool {
        fn drop(&mut self) {
            self.txs.clear(); // close channels; lanes exit
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::ComputePool;

/// Stub pool for builds without the PJRT toolchain: construction always
/// fails with a descriptive error (after validating the artifact
/// metadata, so missing-artifact errors stay identical to the real
/// pool's).
#[cfg(not(feature = "pjrt"))]
pub struct ComputePool {
    /// Keeps the stub unconstructible outside this module: only `new`
    /// can build one, and `new` always errors.
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl ComputePool {
    /// Always errors: the build lacks the `pjrt` feature.
    pub fn new(dir: PathBuf, lanes: usize) -> Result<Self> {
        assert!(lanes > 0);
        let _dims = ModelDims::from_meta_file(&dir.join("model_meta.txt"))?;
        anyhow::bail!(
            "sgc was built without the `pjrt` feature; real-compute training needs \
             the xla crate: add `xla = \"0.1\"` under [dependencies] in rust/Cargo.toml \
             (requires a prebuilt xla_extension install — see the comment there), \
             then rebuild with `cargo build --features pjrt`"
        )
    }

    /// Unreachable (the stub cannot be constructed).
    pub fn dims(&self) -> ModelDims {
        unreachable!("ComputePool cannot be constructed without the pjrt feature")
    }

    /// Unreachable (the stub cannot be constructed).
    pub fn submit(&self, _req: GradRequest) -> Receiver<GradResult> {
        unreachable!("ComputePool cannot be constructed without the pjrt feature")
    }

    /// Unreachable (the stub cannot be constructed).
    pub fn grad_chunk_blocking(&self, _req: GradRequest) -> GradResult {
        unreachable!("ComputePool cannot be constructed without the pjrt feature")
    }
}
