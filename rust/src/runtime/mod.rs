//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from worker threads.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids cleanly (see
//! /opt/xla-example/README.md).

pub mod artifact;
pub mod pool;

pub use artifact::{artifacts_dir, GradExecutable, ModelDims};
pub use pool::{ComputePool, GradRequest};
