//! PJRT runtime: load the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and execute them from worker threads.
//!
//! HLO **text** is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids cleanly (see
//! /opt/xla-example/README.md).
//!
//! The PJRT execution path (the `xla` crate) is gated behind the `pjrt`
//! cargo feature because it needs a prebuilt `xla_extension` install that
//! offline/CI environments lack. Artifact metadata parsing and the
//! [`ComputePool`] API surface compile either way; without the feature,
//! [`ComputePool::new`] fails with instructions instead of executing.

pub mod artifact;
pub mod pool;

#[cfg(feature = "pjrt")]
pub use artifact::GradExecutable;
pub use artifact::{artifacts_dir, ModelDims};
pub use pool::{ComputePool, GradRequest};
