//! AOT artifact loading and execution.
//!
//! `python/compile/aot.py` emits:
//!
//! * `artifacts/model.hlo.txt` — HLO text of the fused (loss, grads)
//!   program over one padded data chunk, lowered from the L2 JAX model
//!   (which calls the L1 Pallas dense kernels).
//! * `artifacts/model_meta.txt` — `key=value` lines describing the
//!   tensor shapes the program was lowered for.
//!
//! The program signature is
//! `(W1, b1, W2, b2, W3, b3, x[chunk,input], y[chunk,classes], wgt[chunk])
//!  → (loss_sum, gW1, gb1, gW2, gb2, gW3, gb3)`
//! with per-sample weights so that padded rows (weight 0) contribute
//! nothing and partial gradients over chunks sum to the full-batch
//! gradient.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shapes of the compiled model program (must match `model_meta.txt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Input feature width.
    pub input: usize,
    /// Output class count.
    pub classes: usize,
    /// First hidden-layer width.
    pub hidden1: usize,
    /// Second hidden-layer width.
    pub hidden2: usize,
    /// Padded chunk size the program was lowered for.
    pub chunk: usize,
}

impl ModelDims {
    /// Parameter tensor shapes in program order.
    pub fn param_shapes(&self) -> Vec<(usize, usize)> {
        vec![
            (self.input, self.hidden1),
            (1, self.hidden1),
            (self.hidden1, self.hidden2),
            (1, self.hidden2),
            (self.hidden2, self.classes),
            (1, self.classes),
        ]
    }

    /// Flattened length of each parameter tensor.
    pub fn param_lens(&self) -> Vec<usize> {
        self.param_shapes().iter().map(|(a, b)| a * b).collect()
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.param_lens().iter().sum()
    }

    /// Parse `model_meta.txt`.
    pub fn from_meta_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let get = |key: &str| -> Result<usize> {
            text.lines()
                .find_map(|l| l.strip_prefix(&format!("{key}=")))
                .with_context(|| format!("missing {key} in {}", path.display()))?
                .trim()
                .parse()
                .with_context(|| format!("bad {key} in {}", path.display()))
        };
        Ok(ModelDims {
            input: get("input")?,
            classes: get("classes")?,
            hidden1: get("hidden1")?,
            hidden2: get("hidden2")?,
            chunk: get("chunk")?,
        })
    }
}

/// Artifact directory: `$SGC_ARTIFACTS` or `artifacts/` relative to the
/// crate root.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("SGC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// A compiled (loss, grads) program on a PJRT CPU client.
///
/// `PjRtClient` is not `Send` (Rc internally): each executable lives on
/// the thread that created it. Cross-thread execution goes through
/// [`super::pool::ComputePool`].
///
/// Gated behind the `pjrt` feature: the `xla` crate needs a prebuilt
/// `xla_extension` install, which offline/CI environments lack. Without
/// the feature, [`super::pool::ComputePool::new`] returns a descriptive
/// error instead.
#[cfg(feature = "pjrt")]
pub struct GradExecutable {
    /// Shapes the program was lowered for.
    pub dims: ModelDims,
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl GradExecutable {
    /// Load and compile `model.hlo.txt` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let dims = ModelDims::from_meta_file(&dir.join("model_meta.txt"))?;
        let hlo = dir.join("model.hlo.txt");
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling model HLO")?;
        Ok(GradExecutable { dims, _client: client, exe })
    }

    /// Compute `(loss_sum, grads)` for one padded chunk.
    ///
    /// * `params` — 6 flattened tensors per [`ModelDims::param_shapes`].
    /// * `x` — `chunk × input`, row-major.
    /// * `y` — `chunk × classes` one-hot.
    /// * `wgt` — `chunk` per-sample weights (0 for padding).
    pub fn grad_chunk(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[f32],
        wgt: &[f32],
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let d = &self.dims;
        anyhow::ensure!(params.len() == 6, "expected 6 parameter tensors");
        for (p, len) in params.iter().zip(d.param_lens()) {
            anyhow::ensure!(p.len() == len, "param length {} != {len}", p.len());
        }
        anyhow::ensure!(x.len() == d.chunk * d.input, "x length");
        anyhow::ensure!(y.len() == d.chunk * d.classes, "y length");
        anyhow::ensure!(wgt.len() == d.chunk, "wgt length");

        let mut args: Vec<xla::Literal> = Vec::with_capacity(9);
        for (p, (r, c)) in params.iter().zip(d.param_shapes()) {
            let lit = xla::Literal::vec1(p);
            args.push(if r == 1 {
                lit.reshape(&[c as i64])?
            } else {
                lit.reshape(&[r as i64, c as i64])?
            });
        }
        args.push(xla::Literal::vec1(x).reshape(&[d.chunk as i64, d.input as i64])?);
        args.push(xla::Literal::vec1(y).reshape(&[d.chunk as i64, d.classes as i64])?);
        args.push(xla::Literal::vec1(wgt));

        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 7, "expected 7 outputs, got {}", outs.len());
        let mut it = outs.into_iter();
        let loss = it.next().unwrap().to_vec::<f32>()?[0];
        let grads: Vec<Vec<f32>> =
            it.map(|l| l.to_vec::<f32>()).collect::<std::result::Result<_, _>>()?;
        Ok((loss, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parsing() {
        let dir = std::env::temp_dir().join("sgc-meta-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model_meta.txt");
        std::fs::write(&p, "input=64\nclasses=10\nhidden1=128\nhidden2=64\nchunk=32\n").unwrap();
        let d = ModelDims::from_meta_file(&p).unwrap();
        assert_eq!(d, ModelDims { input: 64, classes: 10, hidden1: 128, hidden2: 64, chunk: 32 });
        assert_eq!(d.param_count(), 64 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10);
    }

    #[test]
    fn meta_missing_key_errors() {
        let dir = std::env::temp_dir().join("sgc-meta-test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model_meta.txt");
        std::fs::write(&p, "input=64\n").unwrap();
        assert!(ModelDims::from_meta_file(&p).is_err());
    }

    // Execution tests live in rust/tests/end_to_end.rs (need artifacts).
}
