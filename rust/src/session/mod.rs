//! Sans-IO round-protocol engine (Sec. 2, Remark 2.3).
//!
//! [`SgcSession`] owns everything the paper's master *decides* — scheme
//! state, μ-rule straggler detection, wait-out policy, tolerance
//! conformance, job ledgers and run metrics — but performs no IO and
//! knows nothing about how tasks execute. Drivers pump it through a
//! pull/push protocol:
//!
//! 1. [`begin_round`](SgcSession::begin_round) (or the buffer-reusing
//!    [`begin_round_into`](SgcSession::begin_round_into)) → a
//!    [`RoundPlan`] with the per-worker tasks and normalized loads,
//! 2. [`submit`](SgcSession::submit) / [`submit_all`](SgcSession::submit_all)
//!    push per-worker completion times back (from a simulator, a recorded
//!    trace, or real workers),
//! 3. [`close_round`](SgcSession::close_round) applies the μ-rule and the
//!    wait-out policy, commits the round into the scheme, decodes newly
//!    complete jobs and reports what happened as [`SessionEvent`]s.
//!
//! The same engine therefore backs metadata simulation
//! ([`crate::coordinator::Master`]), real-compute PJRT training
//! ([`crate::train::MultiModelTrainer`]), the probe's profile replays and
//! the concurrent batch driver ([`run_parallel`]) without duplicating any
//! round-decision logic. The steady-state round loop reuses session-owned
//! scratch buffers end to end and draws GC decode solvers from the
//! process-wide [`CodePlanCache`] — see `rust/DESIGN.md` §Performance for
//! the allocation and sharing invariants.
//!
//! # Example
//!
//! Pump a session by hand over a simulated cluster (any source of
//! per-worker completion times works — that is the point):
//!
//! ```
//! use sgc::cluster::{Cluster, EventCluster, SimCluster};
//! use sgc::coding::SchemeConfig;
//! use sgc::session::{SessionConfig, SessionEvent, SgcSession};
//! use sgc::straggler::GilbertElliot;
//!
//! let n = 8;
//! let mut cluster =
//!     SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 7).sync();
//! let mut session =
//!     SgcSession::new(&SchemeConfig::gc(n, 1), SessionConfig { jobs: 4, ..Default::default() });
//! let mut decoded = 0;
//! while !session.is_complete() {
//!     let plan = session.begin_round();                // pull: per-worker loads
//!     let sample = cluster.sample_round(&plan.loads);  // execute anywhere
//!     session.submit_all(&sample.finish);              // push: completion times
//!     for event in session.close_round() {             // μ-rule / wait-out / decode
//!         if let SessionEvent::JobDecoded { .. } = event {
//!             decoded += 1;
//!         }
//!     }
//! }
//! assert_eq!(decoded, 4, "every job decodes");
//! let report = session.into_report();
//! assert_eq!(report.rounds.len(), 4);
//! assert_eq!(report.deadline_violations, 0);
//! ```

mod driver;

pub use driver::{default_threads, drive, run_parallel, BatchItem};
// The event-native single-run driver lives with the scheduler; re-export
// it next to `drive` so callers pick per backend flavour, not per module.
pub use crate::sched::drive_events;

use crate::coding::{CodePlanCache, Scheme, SchemeConfig, TaskDesc, ToleranceSpec};
use crate::coordinator::metrics::{RoundRecord, RunReport};
use crate::straggler::{Pattern, ToleranceChecker};
use crate::util::timer::Stopwatch;

/// Wait-out policy applied when the observed straggler pattern exceeds
/// what the scheme was designed for (see `rust/DESIGN.md` §Wait-out
/// policies for the full semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaitPolicy {
    /// Remark 2.3 (paper default): wait for stragglers, in completion
    /// order, until the effective pattern conforms to the design model.
    /// Every job then decodes by its deadline (Props 3.1/3.2), so no
    /// deadline is ever violated.
    ConformanceRepair,
    /// Lazy ablation: only wait when the job due this round cannot be
    /// decoded. Under M-SGC a job may *miss its deadline permanently*:
    /// earlier non-conforming rounds can leave partial gradients
    /// unattempted, and waiting at the deadline round cannot recover work
    /// that was never assigned (`rust/DESIGN.md` §Wait-out policies).
    DeadlineDecode,
    /// Wait for every worker in every round (the uncoded baseline's
    /// behaviour; also forced whenever the scheme tolerates no
    /// stragglers).
    WaitAll,
    /// Degraded-mode approximate decode: never wait past the μ-cutoff,
    /// no matter what the conformance checker or the job ledger say.
    /// Every round closes at `(1+μ)·κ` with whatever responder set
    /// arrived; jobs whose partials were lost simply never decode
    /// (`job_completion_s` stays `NaN`, counted as deadline
    /// violations). This is the always-on serving fallback for a
    /// roster that has shrunk below the scheme's straggler tolerance —
    /// the best available partial sum instead of an indefinite wait
    /// (see `rust/DESIGN.md` §Failure domains). Unlike the other
    /// policies it is *not* overridden to `WaitAll` for zero-tolerance
    /// schemes: an explicit request for degraded mode wins.
    NeverWait,
}

/// Protocol configuration for one session (previously `RunConfig`).
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Number of jobs `J`.
    pub jobs: usize,
    /// Straggler-detection tolerance μ (paper uses 1.0; Appendix L uses
    /// 5.0 for the storage-bound workload).
    pub mu: f64,
    /// What to do when the observed pattern exceeds the design model.
    pub wait_policy: WaitPolicy,
    /// Measure real GC decode solves and record their cost (Table 4).
    pub measure_decode: bool,
    /// Appendix K: when pipelining M > T+1 models, decode hides in the
    /// master's idle time and does not extend rounds.
    pub decode_in_idle: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            jobs: 100,
            mu: 1.0,
            wait_policy: WaitPolicy::ConformanceRepair,
            measure_decode: false,
            decode_in_idle: true,
        }
    }
}

/// What the driver must execute for one round: per-worker tasks and the
/// normalized load each task implies. Reusable: hand the same plan back
/// to [`SgcSession::begin_round_into`] every round and its buffers are
/// refilled in place.
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// 1-based round index.
    pub round: usize,
    /// Task per worker (index = worker id).
    pub tasks: Vec<TaskDesc>,
    /// Normalized load per worker (what a latency model needs).
    pub loads: Vec<f64>,
}

/// What happened when a round was closed.
#[derive(Clone, Debug, PartialEq)]
pub enum SessionEvent {
    /// `close_round` was called before every worker's completion time was
    /// submitted; the round stays open. Submit the listed workers and
    /// close again.
    WaitingFor { workers: Vec<usize> },
    /// The round committed with the given wall-clock duration;
    /// `waited_out` workers were admitted past the μ-cutoff by the
    /// wait-out policy.
    RoundClosed { round: usize, duration_s: f64, waited_out: usize },
    /// A job became decodable at absolute session time `at_s`.
    JobDecoded { job: usize, at_s: f64 },
    /// The job due this round was not decodable at its deadline.
    DeadlineViolated { job: usize, round: usize },
    /// All `J + T` rounds have committed.
    RunComplete { total_runtime_s: f64 },
}

/// Scalar outcome of the μ-rule + wait-out decision for one round (the
/// responder set itself lands in the session's scratch buffers).
#[derive(Clone, Copy, Debug)]
struct DecisionStats {
    duration: f64,
    kappa: f64,
    detected: usize,
    admitted: usize,
}

/// Session-owned per-round scratch, reused across every round so the
/// steady-state decision path performs no heap allocation (§Perf).
#[derive(Default)]
struct RoundScratch {
    /// Dense completion times for the decision procedure.
    finish: Vec<f64>,
    /// Responder set under construction.
    responded: Vec<bool>,
    /// `!responded`, maintained incrementally for the conformance checker.
    stragglers: Vec<bool>,
    /// Non-responders in completion order (wait-out admission queue).
    order: Vec<usize>,
    /// Jobs decoded by the closing round.
    completed: Vec<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Between rounds: the next call must be `begin_round`.
    Ready,
    /// A round is open: accepting `submit` until `close_round`.
    Collecting,
}

/// The sans-IO protocol engine. See the [module docs](self) for the
/// driving protocol.
pub struct SgcSession {
    scheme: Box<dyn Scheme>,
    cfg: SessionConfig,
    /// Effective policy: `WaitAll` whenever the scheme tolerates no
    /// stragglers, else `cfg.wait_policy`.
    wait_policy: WaitPolicy,
    checker: ToleranceChecker,
    phase: Phase,
    /// Last begun round (0 before the first `begin_round`).
    round: usize,
    total_rounds: usize,
    /// Set by [`finish_after_assigned`](Self::finish_after_assigned):
    /// the run was capped at this many paper-jobs (the adaptive
    /// hot-swap's drain mechanism); `None` for a normal full run.
    truncated_jobs: Option<usize>,
    n: usize,
    /// Completion times submitted for the open round.
    finish: Vec<Option<f64>>,
    /// Workers without a submitted time for the open round (incremental,
    /// so streaming drivers poll emptiness in O(1)).
    pending_count: usize,
    /// Fastest completion time submitted for the open round (κ;
    /// `INFINITY` before the first submission). Tracked incrementally so
    /// [`deadline_hint`](Self::deadline_hint) is O(1) on the multi-job
    /// scheduler's per-event path.
    kappa: f64,
    /// Final responder set of the last closed round.
    responded: Vec<bool>,
    scratch: RoundScratch,
    clock: f64,
    rounds: Vec<RoundRecord>,
    job_done: Vec<bool>,
    job_completion: Vec<f64>,
    /// First job that might still be pending: jobs decode (almost) in
    /// order, so the per-round decode scan is O(T) instead of O(J).
    frontier: usize,
    violations: usize,
    true_pattern: Pattern,
    detected_pattern: Pattern,
    // Report identity (from the builder config).
    scheme_label: String,
    scheme_load: f64,
    scheme_delay: usize,
}

impl SgcSession {
    /// Build a session for `cfg.jobs` jobs of the configured scheme.
    pub fn new(scheme_cfg: &SchemeConfig, cfg: SessionConfig) -> Self {
        let scheme = scheme_cfg.build(cfg.jobs);
        let n = scheme.spec().n;
        let total_rounds = scheme.total_rounds();
        // Zero-tolerance schemes must normally wait for everyone — but
        // an explicit NeverWait (degraded serving) takes precedence:
        // waiting forever on a shrunken roster is exactly what degraded
        // mode exists to avoid.
        let wait_policy = if matches!(scheme.spec().tolerance, ToleranceSpec::None)
            && cfg.wait_policy != WaitPolicy::NeverWait
        {
            WaitPolicy::WaitAll
        } else {
            cfg.wait_policy
        };
        let checker = ToleranceChecker::new(n, scheme.spec().tolerance.clone());
        let jobs = cfg.jobs;
        SgcSession {
            scheme,
            cfg,
            wait_policy,
            checker,
            phase: Phase::Ready,
            round: 0,
            total_rounds,
            truncated_jobs: None,
            n,
            finish: vec![None; n],
            pending_count: 0,
            kappa: f64::INFINITY,
            responded: Vec::new(),
            scratch: RoundScratch::default(),
            clock: 0.0,
            rounds: Vec::with_capacity(total_rounds),
            job_done: vec![false; jobs],
            job_completion: vec![f64::NAN; jobs],
            frontier: 1,
            violations: 0,
            true_pattern: Pattern::new(n),
            detected_pattern: Pattern::new(n),
            scheme_label: scheme_cfg.label(),
            scheme_load: scheme_cfg.load(),
            scheme_delay: scheme_cfg.delay(),
        }
    }

    /// Number of workers `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of jobs `J`.
    pub fn jobs(&self) -> usize {
        self.cfg.jobs
    }

    /// Total rounds `J + T`.
    pub fn total_rounds(&self) -> usize {
        self.total_rounds
    }

    /// Last begun round (0 before the first).
    pub fn current_round(&self) -> usize {
        self.round
    }

    /// Absolute session clock (sum of committed round durations).
    pub fn clock_s(&self) -> f64 {
        self.clock
    }

    /// Deadline violations committed so far.
    pub fn deadline_violations(&self) -> usize {
        self.violations
    }

    /// The scheme state (read-only): ledgers, deadlines, decodability.
    pub fn scheme(&self) -> &dyn Scheme {
        self.scheme.as_ref()
    }

    /// Final responder set of the last closed round (empty before the
    /// first close).
    pub fn last_responded(&self) -> &[bool] {
        &self.responded
    }

    /// Per-worker completion times submitted for the current (or most
    /// recently closed) round — `None` for workers whose result never
    /// arrived (cut stragglers). Reset by the next `begin_round*`; the
    /// adaptive profiler's [`crate::sched::RoundObserver`] impl reads
    /// the just-closed round's times from here.
    pub fn last_finish(&self) -> &[Option<f64>] {
        &self.finish
    }

    /// Have all `J + T` rounds committed?
    pub fn is_complete(&self) -> bool {
        self.round >= self.total_rounds && self.phase == Phase::Ready
    }

    /// Paper-jobs assigned so far: round `r` assigns job `r` (up to the
    /// job cap), so this is `current_round.min(jobs)` — with the cap
    /// lowered by [`finish_after_assigned`](Self::finish_after_assigned)
    /// on a truncated session.
    pub fn assigned_jobs(&self) -> usize {
        self.round.min(self.truncated_jobs.unwrap_or(self.cfg.jobs))
    }

    /// Number of jobs decoded as a contiguous prefix `1..=k`: every job
    /// in `1..=decoded_prefix()` has decoded; job
    /// `decoded_prefix() + 1` has not (yet). This is the safe
    /// truncation point for a failed session — the failure-domain
    /// scheduler re-queues a faulted job from here, guaranteed not to
    /// drop or double-count a paper-job.
    pub fn decoded_prefix(&self) -> usize {
        self.frontier - 1
    }

    /// Is the job ledger clean — has every assigned job been decoded?
    /// Meaningful between rounds (after a close); this is the swap
    /// boundary's continuity invariant: a session whose ledger is clean
    /// can be replaced by a fresh one for the remaining jobs without
    /// dropping work.
    pub fn ledger_clean(&self) -> bool {
        self.frontier > self.assigned_jobs()
    }

    /// Cap the run at the paper-jobs assigned so far: the session runs
    /// only its decode tail (`T` more rounds, during which tail
    /// assignments for jobs beyond the cap still execute but are not
    /// counted) and then completes. This is how the adaptive scheduler
    /// drains a session toward a hot-swap boundary — under
    /// [`WaitPolicy::ConformanceRepair`] every capped job decodes by
    /// its deadline inside the tail, so the truncated session ends with
    /// a clean ledger. Returns the cap. Idempotent; must be called
    /// between rounds.
    pub fn finish_after_assigned(&mut self) -> usize {
        assert_eq!(self.phase, Phase::Ready, "finish_after_assigned inside an open round");
        if self.truncated_jobs.is_none() {
            let cap = self.round.min(self.cfg.jobs);
            self.truncated_jobs = Some(cap);
            self.total_rounds = self.total_rounds.min(cap + self.scheme_delay);
        }
        self.truncated_jobs.expect("just set")
    }

    /// Open the next round into a caller-owned (reusable) plan: advances
    /// the scheme's assignment and refills `plan`'s task and load buffers
    /// in place. On the steady-state path this allocates nothing — task
    /// chunk lists are shared `Arc` slices and the buffers keep their
    /// capacity round over round.
    ///
    /// Panics if the previous round is still open or the run is complete.
    pub fn begin_round_into(&mut self, plan: &mut RoundPlan) {
        assert_eq!(self.phase, Phase::Ready, "begin_round while a round is open");
        assert!(!self.is_complete(), "begin_round on a complete session");
        self.round += 1;
        let r = self.round;
        plan.round = r;
        self.scheme.assign_round_into(r, &mut plan.tasks);
        let spec = self.scheme.spec();
        plan.loads.clear();
        plan.loads.extend(plan.tasks.iter().map(|t| spec.task_load(t)));
        for f in self.finish.iter_mut() {
            *f = None;
        }
        self.pending_count = self.n;
        self.kappa = f64::INFINITY;
        self.phase = Phase::Collecting;
    }

    /// Allocating convenience wrapper over
    /// [`begin_round_into`](Self::begin_round_into).
    pub fn begin_round(&mut self) -> RoundPlan {
        let mut plan = RoundPlan::default();
        self.begin_round_into(&mut plan);
        plan
    }

    /// Push one worker's completion time (seconds from round start) for
    /// the open round. Re-submitting overwrites the stored time (κ —
    /// and hence [`deadline_hint`](Self::deadline_hint) — only ever
    /// tightens, so overwriting with a *larger* time does not raise the
    /// hint; production drivers only ever re-submit identical values).
    pub fn submit(&mut self, worker: usize, finish_s: f64) {
        assert_eq!(self.phase, Phase::Collecting, "submit outside an open round");
        assert!(worker < self.n, "worker {worker} out of range (n={})", self.n);
        assert!(
            finish_s.is_finite(),
            "worker {worker} completion time must be finite, got {finish_s}"
        );
        if self.finish[worker].is_none() {
            self.pending_count -= 1;
        }
        self.finish[worker] = Some(finish_s);
        if finish_s < self.kappa {
            self.kappa = finish_s;
        }
    }

    /// Push every worker's completion time at once.
    pub fn submit_all(&mut self, finish_s: &[f64]) {
        assert_eq!(finish_s.len(), self.n, "finish length mismatch");
        for (i, &f) in finish_s.iter().enumerate() {
            self.submit(i, f);
        }
    }

    /// Record the ground-truth straggler states for the open round
    /// (optional; simulators know them, real clusters do not). Feeds the
    /// report's `true_pattern` for Fig.-1-style analysis.
    pub fn record_true_state(&mut self, state: &[bool]) {
        assert_eq!(self.phase, Phase::Collecting, "record_true_state outside an open round");
        assert_eq!(state.len(), self.n, "state length mismatch");
        assert_eq!(
            self.true_pattern.rounds(),
            self.round - 1,
            "true state already recorded for round {}",
            self.round
        );
        self.true_pattern.push_round(state.to_vec());
    }

    /// The committed record of the most recently closed round — κ, the
    /// detected-straggler count, the wait-out flag and the protocol
    /// round duration. Observability layers journal the μ-cut decision
    /// from here at round close instead of re-deriving it; `None`
    /// before the first round commits.
    pub fn last_round(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    /// Workers whose completion time has not been submitted for the open
    /// round (empty outside a round).
    pub fn pending_workers(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.pending_workers_into(&mut out);
        out
    }

    /// Allocation-free variant of [`pending_workers`](Self::pending_workers):
    /// clears and refills a caller-owned buffer. This is what the
    /// scheduler and fleet hot loops poll every arrival batch, so the
    /// steady-state pump stays inside the §Perf allocation budget.
    pub fn pending_workers_into(&self, out: &mut Vec<usize>) {
        out.clear();
        if self.phase != Phase::Collecting {
            return;
        }
        out.extend((0..self.n).filter(|&i| self.finish[i].is_none()));
    }

    /// Workers still missing a completion time for the open round (0
    /// outside a round). O(1) — safe to poll per event in a multi-job
    /// scheduler's hot loop.
    pub fn pending_count(&self) -> usize {
        if self.phase != Phase::Collecting {
            return 0;
        }
        self.pending_count
    }

    /// Is any completion time still missing for the open round?
    fn has_pending(&self) -> bool {
        self.pending_count > 0
    }

    /// μ-rule cutoff hint for the open round: `(1 + μ) · κ` where `κ` is
    /// the fastest completion time submitted so far. This is the earliest
    /// wall-clock instant (seconds from round start) at which
    /// [`try_close_round`](Self::try_close_round) can cut the workers
    /// that have not responded yet. `None` before the first submission
    /// (κ is unknown) or outside a round. O(1): κ is tracked
    /// incrementally by [`submit`](Self::submit).
    ///
    /// A streaming driver polls [`try_close_round`](Self::try_close_round)
    /// on every arrival and sleeps until this hint in between — the
    /// missing piece that lets a real fleet cut stragglers without
    /// waiting for all `n` submissions.
    pub fn deadline_hint(&self) -> Option<f64> {
        if self.phase != Phase::Collecting {
            return None;
        }
        if self.kappa.is_finite() {
            Some((1.0 + self.cfg.mu) * self.kappa)
        } else {
            None
        }
    }

    /// Incremental close for streaming drivers: attempt to close the open
    /// round at wall-clock time `now_s` (seconds from round start) with
    /// only the completion times submitted so far.
    ///
    /// Contract: the driver submits each worker's time as it arrives, so
    /// every still-missing worker is guaranteed to finish *after*
    /// `now_s`. Once `now_s` passes the [`deadline_hint`](Self::deadline_hint)
    /// cutoff, missing workers are therefore provably beyond the μ-rule
    /// cutoff and can be cut without knowing their eventual times —
    /// unless the wait-out policy needs one of them, in which case the
    /// round stays open ([`SessionEvent::WaitingFor`]) and the driver
    /// keeps waiting for arrivals.
    ///
    /// Closing through this path with the workers that did arrive
    /// produces the same responder set, duration and events as a
    /// [`close_round`](Self::close_round) fed everyone's true times,
    /// because cut workers' true times all exceed the cutoff.
    pub fn try_close_round(&mut self, now_s: f64) -> Vec<SessionEvent> {
        assert_eq!(self.phase, Phase::Collecting, "try_close_round without an open round");
        assert!(now_s.is_finite() && now_s >= 0.0, "now_s must be finite and non-negative");
        if !self.has_pending() {
            return self.close_round();
        }
        match self.deadline_hint() {
            Some(hint) if now_s >= hint => {}
            // κ unknown or the cutoff has not passed: cannot cut anyone.
            _ => return vec![SessionEvent::WaitingFor { workers: self.pending_workers() }],
        }
        // Missing workers finish strictly after now_s ≥ (1+μ)κ: model
        // them as unboundedly late and let the one decision procedure
        // classify them.
        let mut finish = std::mem::take(&mut self.scratch.finish);
        finish.clear();
        finish.extend(self.finish.iter().map(|f| f.unwrap_or(f64::INFINITY)));
        let stats = self.decide_round(&finish);
        let needs_missing = self
            .scratch
            .responded
            .iter()
            .zip(&finish)
            .any(|(&ok, &f)| ok && f.is_infinite());
        let events = if needs_missing {
            // The wait-out policy needs a worker that has not arrived.
            vec![SessionEvent::WaitingFor { workers: self.pending_workers() }]
        } else {
            self.commit_decision(&finish, stats)
        };
        self.scratch.finish = finish;
        events
    }

    /// Close the open round: apply the μ-rule and wait-out policy to the
    /// submitted times, commit the responder set into the scheme and the
    /// conformance checker, decode every newly complete job, and return
    /// the resulting events.
    ///
    /// If some workers have not submitted yet, returns a single
    /// [`SessionEvent::WaitingFor`] and leaves the round open.
    pub fn close_round(&mut self) -> Vec<SessionEvent> {
        assert_eq!(self.phase, Phase::Collecting, "close_round without an open round");
        if self.has_pending() {
            return vec![SessionEvent::WaitingFor { workers: self.pending_workers() }];
        }
        let mut finish = std::mem::take(&mut self.scratch.finish);
        finish.clear();
        finish.extend(self.finish.iter().map(|f| f.unwrap()));
        let stats = self.decide_round(&finish);
        let events = self.commit_decision(&finish, stats);
        self.scratch.finish = finish;
        events
    }

    /// Run the μ-rule + wait-out decision for the open round on the given
    /// completion times. Writes the responder set into the session's
    /// scratch buffers; no committed state changes.
    fn decide_round(&mut self, finish: &[f64]) -> DecisionStats {
        let r = self.round;
        let deadline_done =
            self.scheme.deadline_job(r).map(|t| self.job_done[t - 1]).unwrap_or(true);
        decide_into(
            finish,
            self.cfg.mu,
            self.wait_policy,
            &self.checker,
            self.scheme.as_ref(),
            r,
            deadline_done,
            &mut self.scratch.responded,
            &mut self.scratch.stragglers,
            &mut self.scratch.order,
        )
    }

    /// Commit a round decision: record patterns, advance the scheme and
    /// checker, decode newly complete jobs, emit events. Reads the
    /// responder set produced by [`decide_into`] from the scratch buffers.
    fn commit_decision(&mut self, finish: &[f64], stats: DecisionStats) -> Vec<SessionEvent> {
        let r = self.round;
        let DecisionStats { mut duration, kappa, detected, admitted } = stats;
        self.detected_pattern.push_round(
            finish.iter().map(|&f| f > (1.0 + self.cfg.mu) * kappa).collect(),
        );

        // decide_into maintains stragglers == !responded.
        self.checker.commit(&self.scratch.stragglers);
        self.scheme.commit_round(r, &self.scratch.responded);

        // Decode every newly complete job; optionally time the real
        // linear-algebra decode (drawn from the shared plan cache).
        let mut completed = std::mem::take(&mut self.scratch.completed);
        completed.clear();
        let mut decode_s = 0.0;
        for t in self.frontier..=self.cfg.jobs.min(r) {
            if self.job_done[t - 1] || !self.scheme.decodable(t) {
                continue;
            }
            if self.cfg.measure_decode {
                decode_s += time_decode(self.scheme.as_ref(), t);
            }
            self.job_done[t - 1] = true;
            completed.push(t);
        }
        while self.frontier <= self.cfg.jobs && self.job_done[self.frontier - 1] {
            self.frontier += 1;
        }
        if !self.cfg.decode_in_idle {
            duration += decode_s;
        }
        self.clock += duration;
        for &t in &completed {
            self.job_completion[t - 1] = self.clock;
        }

        let mut events = Vec::with_capacity(2 + completed.len());
        events.push(SessionEvent::RoundClosed {
            round: r,
            duration_s: duration,
            waited_out: admitted,
        });
        for &t in &completed {
            events.push(SessionEvent::JobDecoded { job: t, at_s: self.clock });
        }
        if let Some(t) = self.scheme.deadline_job(r) {
            if !self.job_done[t - 1] {
                self.violations += 1;
                events.push(SessionEvent::DeadlineViolated { job: t, round: r });
            }
        }
        self.rounds.push(RoundRecord {
            round: r,
            duration_s: duration,
            kappa_s: kappa,
            detected_stragglers: detected,
            waited_out: admitted,
            decode_s,
            jobs_completed: completed.clone(),
        });
        self.scratch.completed = completed;
        self.responded.clear();
        self.responded.extend_from_slice(&self.scratch.responded);
        self.phase = Phase::Ready;
        if self.round == self.total_rounds {
            events.push(SessionEvent::RunComplete { total_runtime_s: self.clock });
        }
        events
    }

    /// Consume the session into the full run report.
    pub fn into_report(self) -> RunReport {
        RunReport {
            scheme: self.scheme_label,
            load: self.scheme_load,
            delay: self.scheme_delay,
            jobs: self.cfg.jobs,
            total_runtime_s: self.clock,
            rounds: self.rounds,
            job_completion_s: self.job_completion,
            deadline_violations: self.violations,
            true_pattern: self.true_pattern,
            effective_pattern: self.checker.pattern().clone(),
            detected_pattern: self.detected_pattern,
        }
    }
}

/// Apply the μ-rule and the wait-out policy to a round's completion
/// times, writing the responder set into `responded` (and its negation
/// into `stragglers`; `order` is the admission-queue scratch). `r` must
/// be the currently assigned, uncommitted round of `scheme`. This is the
/// *only* copy of the round-decision logic; every execution backend
/// reaches it through [`SgcSession::close_round`]. All three buffers are
/// cleared and refilled — reusing them across rounds is what keeps the
/// steady-state decision allocation-free.
#[allow(clippy::too_many_arguments)]
fn decide_into(
    finish: &[f64],
    mu: f64,
    policy: WaitPolicy,
    checker: &ToleranceChecker,
    scheme: &dyn Scheme,
    r: usize,
    deadline_already_done: bool,
    responded: &mut Vec<bool>,
    stragglers: &mut Vec<bool>,
    order: &mut Vec<usize>,
) -> DecisionStats {
    let n = finish.len();
    let kappa = finish.iter().cloned().fold(f64::INFINITY, f64::min);
    let cutoff = (1.0 + mu) * kappa;
    responded.clear();
    responded.extend(finish.iter().map(|&f| f <= cutoff));
    stragglers.clear();
    stragglers.extend(responded.iter().map(|&x| !x));
    let detected = stragglers.iter().filter(|&&x| x).count();
    let mut duration = if detected == 0 {
        finish.iter().cloned().fold(0.0, f64::max)
    } else {
        cutoff
    };

    // Non-responders in completion order; `next` walks the queue as the
    // wait-out policy admits them back.
    order.clear();
    order.extend((0..n).filter(|&i| !responded[i]));
    order.sort_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap());
    let mut admitted = 0usize;
    let mut next = 0usize;
    loop {
        let satisfied = match policy {
            WaitPolicy::WaitAll => responded.iter().all(|&x| x),
            WaitPolicy::ConformanceRepair => checker.acceptable(stragglers),
            WaitPolicy::DeadlineDecode => match scheme.deadline_job(r) {
                Some(t) if !deadline_already_done => scheme.decodable_with(t, r, responded),
                _ => true,
            },
            // Degraded mode: the μ-cut responder set is final, whatever
            // the checker or the ledger would have preferred.
            WaitPolicy::NeverWait => true,
        };
        if satisfied {
            break;
        }
        if next >= order.len() {
            break;
        }
        let w = order[next];
        next += 1;
        responded[w] = true;
        stragglers[w] = false;
        duration = duration.max(finish[w]);
        admitted += 1;
    }

    // Backstop (ConformanceRepair): the deadline job must decode now.
    // The not-yet-admitted suffix of `order` is exactly the remaining
    // non-responders, already in completion order.
    if policy == WaitPolicy::ConformanceRepair {
        if let Some(t) = scheme.deadline_job(r) {
            if !deadline_already_done {
                while !scheme.decodable_with(t, r, responded) {
                    if next >= order.len() {
                        break;
                    }
                    let w = order[next];
                    next += 1;
                    responded[w] = true;
                    stragglers[w] = false;
                    duration = duration.max(finish[w]);
                    admitted += 1;
                }
            }
        }
    }

    DecisionStats { duration, kappa, detected, admitted }
}

/// Time the actual decode work for a job: one coefficient solve per
/// non-trivially coded group (replication groups decode by a trivial sum
/// and cost ~0). Codes come from the process-wide [`CodePlanCache`], so
/// the measured cost reflects what a production master would pay: the
/// first occurrence of a responder set solves, repeats hit the shared
/// cache.
fn time_decode(scheme: &dyn Scheme, job: usize) -> f64 {
    let n = scheme.spec().n;
    let ledger = scheme.ledger(job);
    let sw = Stopwatch::start();
    for (got, &need) in ledger.coded_got.iter().zip(&ledger.coded_need) {
        if need <= 1 || need >= n {
            continue; // replication / degenerate group: trivial decode
        }
        let s = n - need;
        let plan = CodePlanCache::global().get(n, s);
        let mut workers: Vec<usize> = got.iter().cloned().collect();
        workers.sort_unstable();
        workers.truncate(need);
        // The solve is the measured cost; failure here would mean a
        // non-decodable set, which `decodable()` already excluded.
        let _ = plan.decode_coeffs(&workers);
    }
    sw.elapsed_s()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gc_session(n: usize, s: usize, jobs: usize) -> SgcSession {
        SgcSession::new(
            &SchemeConfig::gc(n, s),
            SessionConfig { jobs, ..Default::default() },
        )
    }

    #[test]
    fn protocol_completes_a_quiet_run() {
        let jobs = 5;
        let mut session = gc_session(4, 1, jobs);
        let mut decoded = Vec::new();
        let mut complete = false;
        while !session.is_complete() {
            let plan = session.begin_round();
            assert_eq!(plan.tasks.len(), 4);
            assert_eq!(plan.loads.len(), 4);
            // all workers finish at the same time: nobody straggles
            session.submit_all(&[1.0, 1.0, 1.0, 1.0]);
            for ev in session.close_round() {
                match ev {
                    SessionEvent::JobDecoded { job, .. } => decoded.push(job),
                    SessionEvent::RunComplete { total_runtime_s } => {
                        complete = true;
                        assert!(total_runtime_s > 0.0);
                    }
                    SessionEvent::DeadlineViolated { .. } => panic!("quiet run violated"),
                    _ => {}
                }
            }
        }
        assert!(complete);
        assert_eq!(decoded, (1..=jobs).collect::<Vec<_>>());
        let report = session.into_report();
        assert_eq!(report.rounds.len(), jobs);
        assert_eq!(report.deadline_violations, 0);
    }

    #[test]
    fn reused_plan_matches_fresh_plans() {
        // begin_round_into with one reused plan must hand out the same
        // rounds as allocating begin_round on a twin session.
        let jobs = 6;
        let mut fresh = gc_session(5, 1, jobs);
        let mut reusing = gc_session(5, 1, jobs);
        let mut plan = RoundPlan::default();
        let finish = [1.0, 1.1, 0.9, 1.05, 2.4];
        while !fresh.is_complete() {
            let p = fresh.begin_round();
            reusing.begin_round_into(&mut plan);
            assert_eq!(p.round, plan.round);
            assert_eq!(p.loads, plan.loads);
            assert_eq!(p.tasks.len(), plan.tasks.len());
            for (a, b) in p.tasks.iter().zip(&plan.tasks) {
                assert_eq!(a.units, b.units);
            }
            fresh.submit_all(&finish);
            reusing.submit_all(&finish);
            assert_eq!(fresh.close_round(), reusing.close_round());
        }
        assert!(reusing.is_complete());
        let a = fresh.into_report();
        let b = reusing.into_report();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn close_round_reports_missing_workers() {
        let mut session = gc_session(3, 1, 2);
        session.begin_round();
        session.submit(0, 1.0);
        session.submit(2, 1.0);
        let events = session.close_round();
        assert_eq!(events, vec![SessionEvent::WaitingFor { workers: vec![1] }]);
        // the round is still open; supplying the straggler lets it close
        session.submit(1, 1.2);
        let events = session.close_round();
        assert!(matches!(events[0], SessionEvent::RoundClosed { round: 1, .. }));
    }

    #[test]
    #[should_panic(expected = "begin_round while a round is open")]
    fn begin_round_twice_panics() {
        let mut session = gc_session(3, 1, 2);
        session.begin_round();
        session.begin_round();
    }

    #[test]
    fn uncoded_forces_wait_all() {
        let mut session = SgcSession::new(
            &SchemeConfig::uncoded(4),
            SessionConfig { jobs: 1, ..Default::default() },
        );
        session.begin_round();
        // worker 3 is far beyond the μ-cutoff but must still be waited for
        session.submit_all(&[1.0, 1.0, 1.0, 9.0]);
        let events = session.close_round();
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, waited_out, .. } => {
                assert!((*duration_s - 9.0).abs() < 1e-12, "wait-all must cover the tail");
                assert_eq!(*waited_out, 1);
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert!(session.last_responded().iter().all(|&x| x));
    }

    #[test]
    fn try_close_waits_until_the_cutoff() {
        let mut session = gc_session(4, 1, 1);
        session.begin_round();
        assert_eq!(session.deadline_hint(), None, "κ unknown before any submission");
        session.submit(0, 1.0);
        assert_eq!(session.deadline_hint(), Some(2.0), "(1+μ)κ with μ=1, κ=1");
        session.submit(1, 1.1);
        session.submit(2, 1.2);
        assert_eq!(session.pending_workers(), vec![3]);
        // before the cutoff the missing worker may still make it
        let events = session.try_close_round(1.5);
        assert_eq!(events, vec![SessionEvent::WaitingFor { workers: vec![3] }]);
        // past the cutoff, worker 3 is provably a straggler: cut it
        let events = session.try_close_round(2.0);
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, waited_out, .. } => {
                assert!((*duration_s - 2.0).abs() < 1e-12, "round ends at the cutoff");
                assert_eq!(*waited_out, 0);
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert_eq!(session.last_responded(), &[true, true, true, false]);
        assert!(events.iter().any(|e| matches!(e, SessionEvent::JobDecoded { job: 1, .. })));
    }

    #[test]
    fn try_close_matches_batch_close_on_the_true_times() {
        // Incremental close (missing straggler) and batch close (all
        // times known) must produce identical rounds.
        let finish = [1.0, 1.05, 1.1, 9.0];
        let mut batch = gc_session(4, 1, 2);
        batch.begin_round();
        batch.submit_all(&finish);
        let batch_events = batch.close_round();

        let mut streaming = gc_session(4, 1, 2);
        streaming.begin_round();
        for w in 0..3 {
            streaming.submit(w, finish[w]);
        }
        // wall clock reaches the cutoff before worker 3 (at 9.0) arrives
        let events = streaming.try_close_round(2.1);
        assert_eq!(events, batch_events);
        assert_eq!(streaming.last_responded(), batch.last_responded());
        assert_eq!(streaming.clock_s(), batch.clock_s());
    }

    #[test]
    fn try_close_never_cuts_under_wait_all() {
        let mut session = SgcSession::new(
            &SchemeConfig::uncoded(4),
            SessionConfig { jobs: 1, ..Default::default() },
        );
        session.begin_round();
        for w in 0..3 {
            session.submit(w, 1.0);
        }
        // far past the μ-cutoff, but the uncoded scheme must wait
        let events = session.try_close_round(50.0);
        assert_eq!(events, vec![SessionEvent::WaitingFor { workers: vec![3] }]);
        session.submit(3, 9.0);
        let events = session.try_close_round(50.0);
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, .. } => {
                assert!((*duration_s - 9.0).abs() < 1e-12, "wait-all covers the tail");
            }
            other => panic!("unexpected event {other:?}"),
        }
    }

    #[test]
    fn try_close_waits_for_workers_the_policy_needs() {
        // GC(s=1) tolerates one straggler; with two workers missing the
        // pattern cannot conform, so the round must stay open until one
        // of them arrives.
        let mut session = gc_session(4, 1, 1);
        session.begin_round();
        session.submit(0, 1.0);
        session.submit(1, 1.0);
        let events = session.try_close_round(3.0);
        assert_eq!(events, vec![SessionEvent::WaitingFor { workers: vec![2, 3] }]);
        // worker 2 arrives late; conformance repair admits it and cuts 3
        session.submit(2, 2.5);
        let events = session.try_close_round(3.0);
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, waited_out, .. } => {
                assert!((*duration_s - 2.5).abs() < 1e-12, "waited out to 2.5s");
                assert_eq!(*waited_out, 1);
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert_eq!(session.last_responded(), &[true, true, true, false]);
    }

    #[test]
    fn never_wait_closes_at_the_cutoff_with_missing_workers() {
        // GC(s=1) with two workers missing: ConformanceRepair would
        // hold the round open (the pattern cannot conform), NeverWait
        // cuts at (1+μ)κ and the due job simply fails to decode.
        let mut session = SgcSession::new(
            &SchemeConfig::gc(4, 1),
            SessionConfig { jobs: 1, wait_policy: WaitPolicy::NeverWait, ..Default::default() },
        );
        session.begin_round();
        session.submit(0, 1.0);
        session.submit(1, 1.0);
        let events = session.try_close_round(2.0);
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, waited_out, .. } => {
                assert!((*duration_s - 2.0).abs() < 1e-12, "round ends at (1+μ)κ");
                assert_eq!(*waited_out, 0, "never-wait admits nobody");
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert!(
            events.iter().any(|e| matches!(e, SessionEvent::DeadlineViolated { job: 1, .. })),
            "the undecodable due job is reported, not waited for"
        );
        assert_eq!(session.decoded_prefix(), 0);
        let report = session.into_report();
        assert!(report.job_completion_s[0].is_nan(), "lost job stays NaN");
    }

    #[test]
    fn never_wait_overrides_the_uncoded_wait_all_forcing() {
        let mut session = SgcSession::new(
            &SchemeConfig::uncoded(4),
            SessionConfig { jobs: 1, wait_policy: WaitPolicy::NeverWait, ..Default::default() },
        );
        session.begin_round();
        for w in 0..3 {
            session.submit(w, 1.0);
        }
        // WaitAll would hold for worker 3 forever; degraded mode cuts.
        let events = session.try_close_round(2.0);
        assert!(matches!(events[0], SessionEvent::RoundClosed { .. }));
        assert_eq!(session.last_responded(), &[true, true, true, false]);
    }

    #[test]
    fn decoded_prefix_tracks_the_frontier() {
        let mut session = gc_session(4, 1, 3);
        assert_eq!(session.decoded_prefix(), 0);
        session.begin_round();
        session.submit_all(&[1.0, 1.0, 1.0, 1.0]);
        session.close_round();
        assert_eq!(session.decoded_prefix(), 1, "job 1 decoded in round 1");
    }

    #[test]
    fn straggler_beyond_cutoff_is_excluded_under_gc() {
        // GC(s=1) tolerates one straggler per round: the slow worker is
        // cut off and the round ends at the μ-cutoff.
        let mut session = gc_session(4, 1, 1);
        session.begin_round();
        session.submit_all(&[1.0, 1.0, 1.0, 9.0]);
        let events = session.close_round();
        match &events[0] {
            SessionEvent::RoundClosed { duration_s, waited_out, .. } => {
                assert!((*duration_s - 2.0).abs() < 1e-12, "round ends at (1+μ)κ");
                assert_eq!(*waited_out, 0);
            }
            other => panic!("unexpected event {other:?}"),
        }
        assert_eq!(session.last_responded(), &[true, true, true, false]);
        // the job still decodes this round
        assert!(events.iter().any(|e| matches!(e, SessionEvent::JobDecoded { job: 1, .. })));
    }
}
