//! Drivers that pump a [`SgcSession`] against a [`Cluster`] backend.
//!
//! [`drive`] runs one session to completion against any cluster;
//! [`run_parallel`] fans a batch of independent sessions out over a
//! thread pool — the workhorse behind parameter sweeps
//! ([`crate::probe`]) and repeated-seed evaluation
//! ([`crate::experiments`]). Both contain zero protocol logic: every
//! round decision lives in [`SgcSession`].
//!
//! Both are fallible: a mis-sized cluster (e.g. a fleet that connected
//! fewer workers than the scheme expects) reports a usable
//! [`anyhow::Error`] instead of aborting the process mid-batch.

use super::{RoundPlan, SessionConfig, SessionEvent, SgcSession};
use crate::cluster::Cluster;
use crate::coding::SchemeConfig;
use crate::coordinator::metrics::RunReport;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Run one session to completion against `cluster` and return its
/// report. Errors if the cluster's worker count does not match the
/// scheme's `n`. One [`RoundPlan`] is reused across all `J + T` rounds
/// (§Perf), so the driver side of the loop allocates nothing per round.
pub fn drive(
    scheme_cfg: &SchemeConfig,
    cfg: &SessionConfig,
    cluster: &mut dyn Cluster,
) -> crate::Result<RunReport> {
    let mut session = SgcSession::new(scheme_cfg, cfg.clone());
    anyhow::ensure!(
        cluster.n() == session.n(),
        "cluster has {} workers but scheme {} expects n = {}",
        cluster.n(),
        scheme_cfg.label(),
        session.n()
    );
    let mut plan = RoundPlan::default();
    while !session.is_complete() {
        session.begin_round_into(&mut plan);
        let sample = cluster.sample_round(&plan.loads);
        session.record_true_state(&sample.state);
        session.submit_all(&sample.finish);
        let events = session.close_round();
        debug_assert!(
            !matches!(events.first(), Some(SessionEvent::WaitingFor { .. })),
            "all completion times were submitted"
        );
    }
    Ok(session.into_report())
}

/// One entry of a parallel batch: a scheme plus its session parameters.
#[derive(Clone, Debug)]
pub struct BatchItem {
    /// Coding scheme for this run.
    pub scheme: SchemeConfig,
    /// Protocol parameters for this run.
    pub session: SessionConfig,
}

/// Sensible worker-thread count for batch drivers.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

/// Run many independent sessions concurrently on a thread pool.
///
/// `make_cluster(i, item)` builds the cluster for batch index `i` (seed
/// it from `i` for reproducibility). Reports come back in input order
/// regardless of completion order, so results are deterministic whenever
/// the cluster factory is. The first failing session fails the batch
/// (with its index attached); sessions that panic still panic.
pub fn run_parallel<F>(
    items: Vec<BatchItem>,
    threads: usize,
    make_cluster: F,
) -> crate::Result<Vec<RunReport>>
where
    F: Fn(usize, &BatchItem) -> Box<dyn Cluster + Send> + Send + Sync + 'static,
{
    if threads <= 1 || items.len() <= 1 {
        return items
            .iter()
            .enumerate()
            .map(|(i, item)| {
                let mut cluster = make_cluster(i, item);
                drive(&item.scheme, &item.session, cluster.as_mut())
                    .map_err(|e| e.context(format!("batch item {i}")))
            })
            .collect();
    }
    let pool = ThreadPool::new(threads.min(items.len()));
    let make = Arc::new(make_cluster);
    let handles: Vec<_> = items
        .into_iter()
        .enumerate()
        .map(|(i, item)| {
            let make = Arc::clone(&make);
            pool.submit(move || {
                // Capture panics so the original message reaches the
                // caller instead of a generic "job panicked".
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut cluster = make(i, &item);
                    drive(&item.scheme, &item.session, cluster.as_mut())
                }))
                .map_err(|e| (i, panic_message(e)))
            })
        })
        .collect();
    handles
        .into_iter()
        .enumerate()
        .map(|(i, h)| match h.join() {
            Ok(Ok(report)) => Ok(report),
            Ok(Err(e)) => Err(e.context(format!("batch item {i}"))),
            Err((i, msg)) => panic!("parallel session {i} panicked: {msg}"),
        })
        .collect()
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{EventCluster, SimCluster};
    use crate::straggler::GilbertElliot;

    fn items() -> Vec<BatchItem> {
        ["gc:2", "m-sgc:1,2,4", "uncoded"]
            .into_iter()
            .map(|spec| BatchItem {
                scheme: SchemeConfig::parse(16, spec).unwrap(),
                session: SessionConfig { jobs: 12, ..Default::default() },
            })
            .collect()
    }

    fn cluster_for(i: usize, item: &BatchItem) -> Box<dyn Cluster + Send> {
        let n = item.scheme.n;
        Box::new(
            SimCluster::from_gilbert_elliot(
                n,
                GilbertElliot::new(n, 0.05, 0.6, 31 + i as u64),
                91 + i as u64,
            )
            .sync(),
        )
    }

    #[test]
    fn parallel_batch_matches_sequential() {
        let sequential: Vec<RunReport> = items()
            .iter()
            .enumerate()
            .map(|(i, item)| {
                let mut cluster = cluster_for(i, item);
                drive(&item.scheme, &item.session, cluster.as_mut()).unwrap()
            })
            .collect();
        let parallel = run_parallel(items(), 4, cluster_for).unwrap();
        assert_eq!(parallel.len(), sequential.len());
        for (p, s) in parallel.iter().zip(&sequential) {
            assert_eq!(p.scheme, s.scheme);
            assert_eq!(p.total_runtime_s, s.total_runtime_s);
            assert_eq!(p.job_completion_s, s.job_completion_s);
            assert_eq!(p.deadline_violations, s.deadline_violations);
        }
    }

    #[test]
    fn drive_matches_manual_pump() {
        let cfg = SchemeConfig::msgc(8, 1, 2, 2);
        let session_cfg = SessionConfig { jobs: 10, ..Default::default() };
        let mk = || {
            Box::new(
                SimCluster::from_gilbert_elliot(8, GilbertElliot::new(8, 0.05, 0.6, 5), 17)
                    .sync(),
            )
        };
        let driven = drive(&cfg, &session_cfg, mk().as_mut()).unwrap();

        let mut cluster = mk();
        let mut session = SgcSession::new(&cfg, session_cfg);
        while !session.is_complete() {
            let plan = session.begin_round();
            let sample = cluster.sample_round(&plan.loads);
            session.record_true_state(&sample.state);
            for (w, &f) in sample.finish.iter().enumerate() {
                session.submit(w, f);
            }
            session.close_round();
        }
        let manual = session.into_report();
        assert_eq!(driven.total_runtime_s, manual.total_runtime_s);
        assert_eq!(driven.job_completion_s, manual.job_completion_s);
        assert_eq!(driven.true_pattern, manual.true_pattern);
    }

    #[test]
    fn size_mismatch_is_a_usable_error() {
        let item = BatchItem {
            scheme: SchemeConfig::parse(16, "gc:2").unwrap(),
            session: SessionConfig { jobs: 4, ..Default::default() },
        };
        // cluster has 8 workers, scheme expects 16
        let mut wrong =
            SimCluster::from_gilbert_elliot(8, GilbertElliot::new(8, 0.05, 0.6, 1), 2).sync();
        let err = drive(&item.scheme, &item.session, &mut wrong).unwrap_err();
        assert!(err.to_string().contains("expects n = 16"), "{err}");

        // …and through the batch driver, with the item index attached
        let err = run_parallel(vec![item.clone(), item], 4, |_, _| {
            Box::new(
                SimCluster::from_gilbert_elliot(8, GilbertElliot::new(8, 0.05, 0.6, 1), 2)
                    .sync(),
            ) as Box<dyn Cluster + Send>
        })
        .unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("batch item"), "{chain}");
        assert!(chain.contains("expects n = 16"), "{chain}");
    }
}
