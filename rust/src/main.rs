//! `sgc` — leader entrypoint / CLI.
//!
//! ```text
//! sgc run    --n 256 --scheme m-sgc:1,2,27 --jobs 480 [--mu 1.0] [--seed 7]
//! sgc sweep  --n 256 --schemes gc:15+m-sgc:1,2,27+uncoded --reps 4
//! sgc probe  --n 256 --t-probe 80 --jobs 80
//! sgc train  --n 16 --scheme m-sgc:1,2,4 --models 4 --iters 25
//! sgc info   --n 256 --scheme sr-sgc:2,3,23
//! ```

use sgc::cluster::{Cluster, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::session::{self, BatchItem, SessionConfig};
use sgc::straggler::GilbertElliot;
use sgc::train::{Dataset, DatasetConfig, MultiModelTrainer, TrainConfig};
use sgc::util::cli::Args;
use sgc::util::stats::MeanStd;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("probe") => cmd_probe(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: sgc <run|sweep|probe|train|info> [--n N] [--scheme SPEC] …\n\
                 scheme spec: gc:S | gc-rep:S | sr-sgc:B,W,L | sr-sgc-rep:B,W,L | \
                 m-sgc:B,W,L | m-sgc-rep:B,W,L | uncoded"
            );
            std::process::exit(2);
        }
    }
}

fn ge_cluster(n: usize, seed: u64) -> SimCluster {
    SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, seed), seed ^ 0xc1)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,27"))?;
    let jobs = args.get_parse("jobs", 480usize);
    let seed = args.get_parse("seed", 7u64);
    let mu = args.get_parse("mu", 1.0f64);
    let cfg = SessionConfig {
        jobs,
        mu,
        measure_decode: args.has_flag("measure-decode"),
        ..Default::default()
    };
    let mut cluster = ge_cluster(n, seed);
    let report = session::drive(&scheme, &cfg, &mut cluster);
    println!(
        "{:<18} load={:.4} T={} runtime={:.2}s rounds={} waitouts={} violations={}",
        report.scheme,
        report.load,
        report.delay,
        report.total_runtime_s,
        report.rounds.len(),
        report.waitout_rounds(),
        report.deadline_violations
    );
    if args.has("out") {
        let path = args.get("out", "target/experiments/run.json");
        report.to_json().save(&path)?;
        println!("saved {path}");
    }
    Ok(())
}

/// Run several schemes × several seeds concurrently on the batch driver
/// and summarise per scheme (`--schemes` takes `+`-separated specs).
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let jobs = args.get_parse("jobs", 480usize);
    let reps = args.get_parse("reps", 4usize).max(1);
    let seed = args.get_parse("seed", 7u64);
    let mu = args.get_parse("mu", 1.0f64);
    let specs = args.get("schemes", "m-sgc:1,2,27+sr-sgc:2,3,23+gc:15+uncoded");
    let schemes: Vec<SchemeConfig> = specs
        .split('+')
        .map(|s| SchemeConfig::parse(n, s.trim()))
        .collect::<anyhow::Result<_>>()?;

    let items: Vec<BatchItem> = schemes
        .iter()
        .flat_map(|scheme| {
            (0..reps).map(move |_| BatchItem {
                scheme: scheme.clone(),
                session: SessionConfig { jobs, mu, ..Default::default() },
            })
        })
        .collect();
    let reports = session::run_parallel(items, session::default_threads(), move |i, item| {
        Box::new(ge_cluster(item.scheme.n, seed + (i % reps) as u64)) as Box<dyn Cluster + Send>
    });

    println!(
        "{:<22} {:>8} {:>3} {:>12} {:>10} {:>9}",
        "scheme", "load", "T", "runtime", "±std", "violations"
    );
    for (k, scheme) in schemes.iter().enumerate() {
        let slice = &reports[k * reps..(k + 1) * reps];
        let runtimes: Vec<f64> = slice.iter().map(|r| r.total_runtime_s).collect();
        let stats = MeanStd::of(&runtimes);
        let violations: usize = slice.iter().map(|r| r.deadline_violations).sum();
        println!(
            "{:<22} {:>8.4} {:>3} {:>11.2}s {:>9.2}s {:>9}",
            scheme.label(),
            scheme.load(),
            scheme.delay(),
            stats.mean,
            stats.std,
            violations
        );
    }
    Ok(())
}

fn cmd_probe(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let t_probe = args.get_parse("t-probe", 80usize);
    let jobs = args.get_parse("jobs", 80usize);
    let seed = args.get_parse("seed", 7u64);
    let mut cluster =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, seed), seed ^ 0xc1);
    let profile = DelayProfile::capture(&mut cluster, t_probe, 1.0 / n as f64);
    let alpha = cluster.latency.alpha_s_per_load;
    let space = SearchSpace::paper_default(n);
    for (name, cands) in [
        ("GC", space.gc_candidates()),
        ("SR-SGC", space.sr_sgc_candidates()),
        ("M-SGC", space.m_sgc_candidates()),
    ] {
        let ranked = grid_search(&cands, &profile, alpha, jobs);
        if let Some(best) = ranked.first() {
            println!(
                "{name:<8} best {} load={:.4} est_runtime={:.1}s ({} candidates)",
                best.config.label(),
                best.load,
                best.estimated_runtime_s,
                ranked.len()
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 16usize);
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,4"))?;
    let cfg = TrainConfig {
        models: args.get_parse("models", 4usize),
        iterations: args.get_parse("iters", 25usize),
        batch: args.get_parse("batch", 256usize),
        lr: args.get_parse("lr", 2e-3f32),
        seed: args.get_parse("seed", 7u64),
        ..Default::default()
    };
    let lanes = args.get_parse("lanes", 4usize);
    let pool = std::sync::Arc::new(sgc::runtime::ComputePool::new(
        sgc::runtime::artifacts_dir(),
        lanes,
    )?);
    let dataset = Dataset::generate(DatasetConfig::default());
    let mut trainer = MultiModelTrainer::new(scheme, cfg.clone(), pool, dataset)?;
    let mut cluster = SimCluster::from_gilbert_elliot(
        n,
        GilbertElliot::default_fit(n, cfg.seed),
        cfg.seed ^ 0xc1,
    );
    let report = trainer.run(&mut cluster)?;
    println!(
        "{}: {} jobs in sim {:.1}s (wall {:.1}s), violations={}",
        report.scheme,
        report.jobs_completed,
        report.sim_runtime_s,
        report.wall_runtime_s,
        report.deadline_violations
    );
    for (m, curve) in report.losses.iter().enumerate() {
        if let (Some(first), Some(last)) = (curve.first(), curve.last()) {
            println!(
                "  model {m}: loss {:.4} → {:.4} over {} iterations",
                first.loss, last.loss, last.iteration
            );
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,27"))?;
    let s = scheme.build(1);
    let spec = s.spec();
    println!("scheme:     {}", spec.name);
    println!("n:          {}", spec.n);
    println!("delay T:    {}", spec.delay);
    println!("load L:     {:.6}", spec.load);
    println!("chunks η:   {}", spec.num_chunks);
    println!("tolerance:  {:?}", spec.tolerance);
    Ok(())
}
