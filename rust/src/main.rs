//! `sgc` — leader entrypoint / CLI.
//!
//! ```text
//! sgc run    --n 256 --scheme m-sgc:1,2,27 --jobs 480 [--mu 1.0] [--seed 7]
//!            [--fleet N | --listen ADDR] [--record-trace P] [--replay-trace P]
//! sgc serve  --jobs 4 --scheme gc:2 [--n 16 | --fleet N] [--session-jobs 24]
//!            [--policy disjoint|round-robin] [--mu 1.0] [--seed 7]
//!            [--late-join J] [--join-window S] [--reap-after S]
//!            [--adapt] [--refit-budget K] [--swap-margin FRAC]
//!            [--profile-decay D] [--regime-shift R]
//!            [--metrics ADDR] [--metrics-hold S] [--journal PATH]
//!            [--report-json PATH] [--chaos SPEC] [--chaos-seed S]
//!            [--real-grad]
//!            [--listen-jobs ADDR] [--max-queue N] [--max-active N]
//!            [--oversub F] [--serve-for S]
//! sgc submit --master HOST:PORT [--name NAME] [--scheme SPEC]
//!            [--session-jobs N] [--priority P]
//! sgc trace  export --journal PATH [--out PATH]
//! sgc worker --master HOST:PORT --id K [--chaos-seed S]
//! sgc sweep  --n 256 --schemes gc:15+m-sgc:1,2,27+uncoded --reps 4
//!            [--record-trace PREFIX]
//! sgc probe  --n 256 --t-probe 80 --jobs 80
//! sgc train  --n 16 --scheme m-sgc:1,2,4 --models 4 --iters 25
//! sgc info   --n 256 --scheme sr-sgc:2,3,23
//! ```
//!
//! `sgc run --fleet N` spins an in-process loopback fleet of `N` TCP
//! workers with seeded chaos injection and applies the μ-rule to real
//! wall-clock arrivals; `sgc run --listen 0.0.0.0:7070` instead waits
//! for `--n` external `sgc worker` processes to connect.
//!
//! `sgc serve --jobs N` is the multi-tenant mode: it admits `N`
//! independent SGC sessions onto **one shared cluster** (the simulator
//! by default, a loopback TCP fleet with `--fleet K`) and multiplexes
//! their rounds through the event-driven `JobScheduler`, printing
//! per-job reports plus the aggregate fleet-utilization summary.
//! Fleet mode is elastic: `--late-join J` starts `J` extra workers that
//! `Hello` mid-run, `--join-window S` bounds how long late joins are
//! admitted (absent = forever), and `--reap-after S` retires workers
//! whose heartbeats stay silent. See `rust/docs/OPERATIONS.md`.
//!
//! `--real-grad` (fleet only) puts every served job on the gradient
//! data plane (`sgc::grad`): the master ships dataset partitions and
//! versioned parameters to the workers, workers compute real coded
//! partial gradients over TCP, and the master β-decodes the batch
//! gradient and steps Adam at every paper-job decode — printing each
//! job's loss trajectory alongside the protocol report.
//!
//! `--listen-jobs ADDR` (fleet only) turns `sgc serve` into a
//! long-lived serving loop: the reactor accepts `sgc submit` clients on
//! a control socket (same `poll(2)` fd set as the workers — no extra
//! thread) and the scheduler admits their jobs dynamically with
//! per-priority placement, preemption when the fleet shrinks below
//! aggregate demand, and `--max-queue`-bounded admission backpressure.
//! `--serve-for S` bounds the loop's lifetime (absent = serve until
//! killed); `--max-active` caps concurrently running jobs and
//! `--oversub` sets the demand-to-worker budget ratio.
//!
//! `--adapt` turns on the adaptive control plane (`sgc::adapt`): the
//! scheduler profiles live arrivals, re-fits `(B, W, λ)` in the
//! background (`--refit-budget` candidates per round close), and
//! hot-swaps a job to the re-fitted scheme at a job boundary when the
//! predicted gain clears `--swap-margin` after a detected regime shift.
//! `--regime-shift R` (simulator only) scripts a straggler-regime flip
//! at cluster round `R` — the adaptive-serve smoke input.

use sgc::adapt::AdaptiveConfig;
use sgc::chaos::{ChaosPlan, ResolvedPlan};
use sgc::cluster::{Cluster, EventCluster, RecordingCluster, RunTrace, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::coordinator::RunReport;
use sgc::fleet::{
    self, ChaosConfig, FleetCluster, Frame, LoopbackFleet, MembershipConfig, WorkerConfig,
};
use sgc::grad::{DataPlane, GradConfig, GradJobSummary, GradPump};
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::sched::{
    self, DisjointPlacement, JobScheduler, JobSpec, NoopObserver, PlacementPolicy,
    QueueSource, RoundRobinPlacement, ScheduleReport, ServeConfig,
};
use sgc::session::{self, BatchItem, SessionConfig};
use sgc::straggler::{GilbertElliot, Pattern};
use sgc::train::{Dataset, DatasetConfig, MultiModelTrainer, TrainConfig};
use sgc::util::cli::Args;
use sgc::util::stats::MeanStd;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // --verbose raises the library log facade to info (diagnostics land
    // on stderr; deliberate CLI output stays on stdout). SGC_LOG=debug
    // etc. overrides finer-grained (see sgc::obs::log).
    if args.has_flag("verbose") {
        sgc::obs::log::set_level(sgc::obs::log::Level::Info);
    }
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("submit") => cmd_submit(&args),
        Some("trace") => cmd_trace(&args),
        Some("worker") => cmd_worker(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("probe") => cmd_probe(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: sgc <run|serve|submit|trace|worker|sweep|probe|train|info> [--n N] [--scheme SPEC] …\n\
                 scheme spec: gc:S | gc-rep:S | sr-sgc:B,W,L | sr-sgc-rep:B,W,L | \
                 m-sgc:B,W,L | m-sgc-rep:B,W,L | uncoded\n\
                 fleet:       sgc run --fleet N (loopback workers) or --listen ADDR\n\
                              (+ sgc worker --master ADDR --id K per external worker)\n\
                 multi-job:   sgc serve --jobs N [--fleet K] — N sessions share one cluster\n\
                 elastic:     serve --fleet K --late-join J [--join-window S] [--reap-after S]\n\
                 adaptive:    serve --adapt [--refit-budget K] [--swap-margin FRAC]\n\
                              [--profile-decay D] [--regime-shift R (sim only)]\n\
                 chaos:       serve --chaos crash@r2,hang@r4:w1,shrink@r6:2 [--chaos-seed S]\n\
                              (kinds: crash hang byz part rejoin shrink; deterministic per seed)\n\
                 gradients:   serve --fleet K --real-grad — real coded partial gradients\n\
                 serving:     serve --fleet K --listen-jobs ADDR [--max-queue N]\n\
                              [--max-active N] [--oversub F] [--serve-for S]\n\
                              + sgc submit --master ADDR [--name NAME] [--scheme SPEC]\n\
                              [--session-jobs N] [--priority P] per dynamic job\n\
                 observe:     serve [--metrics ADDR (fleet)] [--metrics-hold S]\n\
                              [--journal PATH] [--report-json PATH]; --verbose anywhere\n\
                              sgc trace export --journal PATH [--out PATH] (Chrome JSON)\n\
                 traces:      --record-trace FILE on run/sweep; --replay-trace FILE on run"
            );
            std::process::exit(2);
        }
    }
}

fn ge_cluster(n: usize, seed: u64) -> SimCluster {
    SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, seed), seed ^ 0xc1)
}

/// The `--round-timeout` flag (shared by every fleet mode).
fn round_timeout(args: &Args) -> Duration {
    Duration::from_secs_f64(args.get_parse("round-timeout", 60.0f64))
}

/// The elastic-membership flags (shared by every fleet mode):
/// `--join-window SECS` (absent = joins always admitted; `0` closes the
/// fleet after startup) and `--reap-after SECS` (heartbeat-silent
/// workers are retired past this).
fn membership(args: &Args) -> MembershipConfig {
    let mut m = MembershipConfig::default();
    if args.has("join-window") {
        m.join_window = Some(Duration::from_secs_f64(args.get_parse("join-window", 0.0f64)));
    }
    m.reap_after = Duration::from_secs_f64(args.get_parse("reap-after", 10.0f64));
    m
}

/// Spin up a loopback TCP fleet per the shared CLI flags
/// (`--no-chaos`, `--chaos-seed`, `--round-timeout`, `--join-window`,
/// `--reap-after`). `plan` is the scripted fault plan from `--chaos`,
/// split across its two injection sites: each worker embeds its own
/// fault ([`ResolvedPlan::worker_fault`]) and the master acts out the
/// shrink/partition entries ([`FleetCluster::set_chaos`]).
fn spawn_loopback(
    args: &Args,
    workers: usize,
    seed: u64,
    plan: Option<&ResolvedPlan>,
) -> anyhow::Result<LoopbackFleet> {
    let chaos = if args.has_flag("no-chaos") {
        None
    } else {
        Some(ChaosConfig::default_fit(args.get_parse("chaos-seed", seed)))
    };
    let mut fleet = LoopbackFleet::spawn_with(workers, |id, addr| {
        let mut cfg = WorkerConfig::loopback(id, addr.to_string(), chaos);
        cfg.fault = plan.and_then(|p| p.worker_fault(id as usize));
        cfg
    })?;
    if let Some(p) = plan {
        fleet.cluster.set_chaos(p.clone());
    }
    fleet.cluster.set_round_timeout(round_timeout(args));
    fleet.cluster.set_membership(membership(args));
    Ok(fleet)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.has_flag("fleet"),
        "--fleet needs a worker count (e.g. --fleet 8)"
    );
    anyhow::ensure!(
        !args.has("chaos"),
        "--chaos needs the failure-domain scheduler: use sgc serve --chaos SPEC"
    );
    let fleet_n = args.options.get("fleet").map(|v| v.parse::<usize>()).transpose()?;
    let n = match fleet_n {
        Some(k) => k,
        None => args.get_parse("n", 256usize),
    };
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,27"))?;
    let jobs = args.get_parse("jobs", 480usize);
    let seed = args.get_parse("seed", 7u64);
    let mu = args.get_parse("mu", 1.0f64);
    let cfg = SessionConfig {
        jobs,
        mu,
        measure_decode: args.has_flag("measure-decode"),
        ..Default::default()
    };
    let record = args.options.get("record-trace").cloned();

    let report: RunReport = if fleet_n.is_some() || args.has("listen") {
        // --- live fleet: wall-clock μ-rule over streaming TCP arrivals ---
        let run = match fleet_n {
            Some(k) => {
                let mut fleet = spawn_loopback(args, k, seed, None)?;
                let run = fleet::drive_fleet(&scheme, &cfg, &mut fleet.cluster)?;
                // join the workers so a worker-side error fails the run
                // instead of disappearing with its thread
                fleet.shutdown()?;
                run
            }
            None => {
                let addr = args.get("listen", "127.0.0.1:7070");
                println!("waiting for {n} workers on {addr} …");
                let mut cluster = FleetCluster::listen(&addr, n, Duration::from_secs(120))?;
                cluster.set_round_timeout(round_timeout(args));
                cluster.set_membership(membership(args));
                let run = fleet::drive_fleet(&scheme, &cfg, &mut cluster)?;
                cluster.shutdown();
                run
            }
        };
        if let Some(path) = &record {
            run.trace.save(path)?;
            println!("recorded trace → {path}");
        }
        run.report
    } else if args.has("replay-trace") {
        // --- exact replay of a recorded delay matrix ---
        let path = args.get("replay-trace", "");
        let trace = RunTrace::load(&path)?;
        anyhow::ensure!(trace.n == n, "trace has n={}, run requested n={n}", trace.n);
        let needed = jobs + scheme.delay();
        anyhow::ensure!(
            trace.rounds() >= needed,
            "trace has {} rounds but --jobs {jobs} needs {needed}; a shorter trace \
             would silently wrap around (pass the jobs count the trace was recorded at)",
            trace.rounds()
        );
        session::drive(&scheme, &cfg, &mut trace.replay().sync())?
    } else {
        // --- stochastic simulator ---
        let mut sim = ge_cluster(n, seed);
        match &record {
            Some(path) => {
                // explicit save so a write failure fails the command
                // (autosave-on-drop can only warn); recording is a
                // blocking wrapper, so bridge the simulator through its
                // SyncAdapter
                let mut rec = RecordingCluster::new(sim.sync());
                let report = session::drive(&scheme, &cfg, &mut rec)?;
                rec.into_trace().save(path)?;
                println!("recorded trace → {path}");
                report
            }
            // event-native scheduler path (identical report, see
            // tests/properties.rs::prop_scheduler_single_job_matches_drive)
            None => sched::drive_events(&scheme, &cfg, &mut sim)?,
        }
    };
    println!(
        "{:<18} load={:.4} T={} runtime={:.2}s rounds={} waitouts={} violations={}",
        report.scheme,
        report.load,
        report.delay,
        report.total_runtime_s,
        report.rounds.len(),
        report.waitout_rounds(),
        report.deadline_violations
    );
    if args.has("out") {
        let path = args.get("out", "target/experiments/run.json");
        report.to_json().save(&path)?;
        println!("saved {path}");
    }
    Ok(())
}

/// Multi-tenant mode: admit `--jobs` independent sessions onto one
/// shared cluster and multiplex them through the `JobScheduler`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.has_flag("fleet"),
        "--fleet needs a worker count (e.g. --fleet 8)"
    );
    // --listen-jobs ADDR: long-lived serving loop fed by a reactor-side
    // control socket (see `sgc submit`). Pre-admitted --jobs default to
    // zero there: the socket is the admission path.
    let listen_jobs = args.options.get("listen-jobs").cloned();
    let jobs = if listen_jobs.is_some() {
        args.get_parse("jobs", 0usize)
    } else {
        args.get_parse("jobs", 4usize).max(1)
    };
    let fleet_n = args.options.get("fleet").map(|v| v.parse::<usize>()).transpose()?;
    let n = match fleet_n {
        Some(k) => k,
        None => args.get_parse("n", 16usize),
    };
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "gc:2"))?;
    let seed = args.get_parse("seed", 7u64);
    // --chaos SPEC: scripted fault plan (e.g. crash@r2,hang@r4:w1),
    // victims resolved deterministically from --chaos-seed — the same
    // seed reproduces the identical fault script (see sgc::chaos).
    let chaos_plan = args
        .options
        .get("chaos")
        .map(|spec| ChaosPlan::parse(spec, args.get_parse("chaos-seed", seed)))
        .transpose()?
        .map(|p| p.resolve(n));
    let cfg = SessionConfig {
        jobs: args.get_parse("session-jobs", 24usize),
        mu: args.get_parse("mu", 1.0f64),
        ..Default::default()
    };
    let policy = || -> anyhow::Result<Box<dyn PlacementPolicy>> {
        match args.get("policy", "disjoint").as_str() {
            "disjoint" => Ok(Box::new(DisjointPlacement)),
            "round-robin" | "rr" => Ok(Box::new(RoundRobinPlacement)),
            other => anyhow::bail!("unknown --policy {other:?} (disjoint | round-robin)"),
        }
    };
    let spec = JobSpec { scheme: scheme.clone(), session: cfg.clone() };

    // --adapt: online profiling, background re-fit, hot-swap at job
    // boundaries (module docs + OPERATIONS.md §adaptive)
    let adaptive = if args.has("adapt") {
        let d = AdaptiveConfig::default();
        let mut acfg = AdaptiveConfig {
            refit_budget: args.get_parse("refit-budget", d.refit_budget),
            ..d
        };
        acfg.policy.swap_margin = args.get_parse("swap-margin", acfg.policy.swap_margin);
        acfg.profiler.fast_decay = args.get_parse("profile-decay", acfg.profiler.fast_decay);
        Some(acfg)
    } else {
        None
    };

    // Observability (sgc::obs): one shared hub feeds the scheduler's
    // metrics/journal hooks, the backend's ground-truth/reactor hooks,
    // and — fleet only — the reactor-served /metrics endpoint.
    anyhow::ensure!(
        fleet_n.is_some() || !args.has("metrics"),
        "--metrics needs a TCP fleet (--fleet N): the simulator has no reactor to serve scrapes"
    );
    anyhow::ensure!(
        fleet_n.is_some() || listen_jobs.is_none(),
        "--listen-jobs needs a TCP fleet (--fleet N): the control socket lives on the reactor"
    );
    // --real-grad: put every job on the gradient data plane — real
    // partitions, params and coded partial gradients over the wire
    // (sgc::grad module docs + OPERATIONS.md §real gradients).
    let real_grad = args.has("real-grad");
    anyhow::ensure!(
        fleet_n.is_some() || !real_grad,
        "--real-grad needs a TCP fleet (--fleet N): partitions and gradients ship over the wire"
    );
    let obs = if args.has("metrics") || args.has("journal") {
        Some(std::sync::Arc::new(sgc::obs::Obs::new()))
    } else {
        None
    };

    let mut grad_summaries: Option<Vec<GradJobSummary>> = None;
    let out: ScheduleReport = match fleet_n {
        Some(k) => {
            // --- one shared loopback TCP fleet for every session ---
            let mut fleet = spawn_loopback(args, k, seed, chaos_plan.as_ref())?;
            // --late-join J: start J extra workers (ids k..k+J) that
            // Hello mid-run — the elastic-membership smoke. They are
            // tracked like the initial workers and joined at shutdown.
            let late = args.get_parse("late-join", 0usize);
            for id in k..k + late {
                let chaos = if args.has_flag("no-chaos") {
                    None
                } else {
                    Some(ChaosConfig::default_fit(args.get_parse("chaos-seed", seed)))
                };
                fleet.join_worker(WorkerConfig::loopback(id as u32, String::new(), chaos));
            }
            if late > 0 {
                println!("late-joining {late} extra workers (ids {k}..{})", k + late - 1);
            }
            if let Some(o) = &obs {
                fleet.cluster.set_obs(o.clone());
            }
            if let Some(addr) = args.options.get("metrics") {
                let bound = fleet.cluster.serve_metrics(addr)?;
                println!("metrics: http://{bound}/metrics");
            }
            // --listen-jobs: open the control socket on the reactor and
            // keep the shared admission queue for the serving loop below.
            let control = match &listen_jobs {
                Some(addr) => {
                    let bound = fleet.cluster.serve_jobs(addr)?;
                    println!("jobs: sgc submit --master {bound} --scheme SPEC");
                    fleet.cluster.control()
                }
                None => None,
            };
            // The pump owns the decode/optimizer side; the same shared
            // data plane is handed to the master (partition/param
            // shipping, payload reassembly) and the scheduler (round
            // staging).
            let mut pump = real_grad.then(|| {
                GradPump::new(DataPlane::shared(), GradConfig { seed, ..Default::default() })
            });
            if let Some(p) = &pump {
                fleet.cluster.set_dataplane(p.dataplane());
            }
            let out = {
                let mut sched = JobScheduler::with_policy(&mut fleet.cluster, policy()?);
                if let Some(acfg) = adaptive.clone() {
                    sched.set_adaptive(acfg);
                }
                if let Some(o) = &obs {
                    sched.set_obs(o.clone());
                }
                if let Some(p) = &pump {
                    sched.set_dataplane(p.dataplane());
                }
                for _ in 0..jobs {
                    let j = sched.admit(&spec)?;
                    if let Some(p) = &mut pump {
                        p.configure_job(j, &spec.scheme)?;
                    }
                }
                match &control {
                    Some(ctrl) => {
                        // Long-lived serving loop: admissions arrive on
                        // the control socket; pre-admitted --jobs (if
                        // any) queue ahead of them.
                        let mut src = QueueSource::new(ctrl.clone(), k, cfg.clone());
                        let scfg = ServeConfig {
                            max_queue: args.get_parse("max-queue", 64usize),
                            max_active: args.get_parse("max-active", 8usize),
                            oversub: args.get_parse("oversub", 4.0f64),
                            serve_for_s: args
                                .options
                                .get("serve-for")
                                .map(|v| v.parse())
                                .transpose()?,
                        };
                        match &mut pump {
                            Some(p) => sched.serve(&mut src, &scfg, p)?,
                            None => sched.serve(&mut src, &scfg, &mut NoopObserver)?,
                        }
                    }
                    None => match &mut pump {
                        Some(p) => sched.run_observed(p)?,
                        None => sched.run()?,
                    },
                }
            };
            if let Some(p) = &pump {
                grad_summaries = Some(p.summary());
            }
            // --metrics-hold S: keep the reactor pumping (and serving
            // /metrics scrapes) for S more seconds so an external
            // scraper can read the final series before shutdown.
            let hold = args.get_parse("metrics-hold", 0.0f64);
            if hold > 0.0 {
                let end = fleet.cluster.now_s() + hold;
                loop {
                    let now = fleet.cluster.now_s();
                    if now >= end {
                        break;
                    }
                    let _ = fleet.cluster.poll((now + 0.25).min(end));
                }
            }
            // drain cut stragglers' late results so every worker is idle
            // before Shutdown (a worker whose Result write fails errors
            // its thread), then join the workers so a worker-side error
            // fails the run instead of disappearing with its thread
            let _ = fleet.cluster.finish_trace(Duration::from_secs(10), cfg.mu);
            fleet.shutdown()?;
            out
        }
        None => {
            // --- one shared simulator for every session ---
            let mut sim = match args.options.get("regime-shift") {
                Some(v) => {
                    // Scripted straggler trace: quiet until the given
                    // cluster round, then a persistent heavy regime
                    // (alternating straggle/clear rows keep each burst
                    // at full severity; the long tail keeps the trace
                    // from wrapping back into the quiet prefix).
                    let shift_at: usize = v.parse()?;
                    let mut rows = vec![vec![false; n]; shift_at];
                    for k in 0..4096usize {
                        rows.push((0..n).map(|w| k % 2 == 0 && w % 3 == 0).collect());
                    }
                    SimCluster::from_trace(n, Pattern::from_rows(rows), seed)
                }
                None => ge_cluster(n, seed),
            };
            if let Some(o) = &obs {
                sim.set_obs(o.clone());
            }
            if let Some(p) = &chaos_plan {
                sim.set_chaos(p.clone());
            }
            let mut sched = JobScheduler::with_policy(&mut sim, policy()?);
            if let Some(acfg) = adaptive.clone() {
                sched.set_adaptive(acfg);
            }
            if let Some(o) = &obs {
                sched.set_obs(o.clone());
            }
            for _ in 0..jobs {
                sched.admit(&spec)?;
            }
            sched.run()?
        }
    };

    for (j, rep) in out.reports.iter().enumerate() {
        let oc = out.outcomes.get(j);
        println!(
            "job {j}: {:<18} {:<11} runtime={:.2}s rounds={} retries={} waitouts={} violations={}",
            rep.scheme,
            oc.map_or("completed", |o| o.status.as_str()),
            rep.total_runtime_s,
            rep.rounds.len(),
            oc.map_or(0, |o| o.retries),
            rep.waitout_rounds(),
            rep.deadline_violations
        );
    }
    for sw in &out.swaps {
        println!("swap: {sw}");
    }
    if let Some(sums) = &grad_summaries {
        for s in sums {
            println!(
                "job {}: loss {:.4} → {:.4} over {} optimizer steps (audits={} fallbacks={})",
                s.job, s.first_loss, s.last_loss, s.steps, s.audits, s.fallback_decodes
            );
        }
    }
    println!("{}", out.utilization);
    if let Some(path) = args.options.get("report-json") {
        let mut doc = out.to_json();
        if let Some(sums) = &grad_summaries {
            doc.set("grad", sgc::util::json::Json::Arr(sums.iter().map(grad_json).collect()));
        }
        doc.save(path)?;
        println!("report → {path}");
    }
    if let Some(o) = &obs {
        if let Some(path) = args.options.get("journal") {
            o.journal.to_json().save(path)?;
            println!("journal ({} events) → {path}", o.journal.len());
        }
    }
    if chaos_plan.is_some() {
        // Failure-domain contract: a scripted chaos run succeeds as long
        // as the blast radius stayed contained — at least one job landed
        // Completed or Degraded. Victims show up as retries/quarantines
        // in the outcomes (and --report-json), not as a nonzero exit.
        anyhow::ensure!(
            !out.all_failed(),
            "chaos run: every job was quarantined — failure domains leaked"
        );
    } else {
        // No scripted faults: every session job must have decoded.
        let undecoded: usize = out
            .reports
            .iter()
            .flat_map(|r| r.job_completion_s.iter())
            .filter(|t| !t.is_finite())
            .count();
        anyhow::ensure!(undecoded == 0, "{undecoded} session jobs never became decodable");
    }
    Ok(())
}

/// Submit one job to a live `sgc serve --listen-jobs` control socket
/// and print the verdict: exit 0 on `Accepted`, nonzero on `Rejected`
/// or a protocol error. One connection, one `Submit`, one reply.
fn cmd_submit(args: &Args) -> anyhow::Result<()> {
    let master = args.get("master", "127.0.0.1:7171");
    let name = args.get("name", "cli-job");
    let scheme = args.get("scheme", "gc:2");
    // 0 = inherit the server's --session-jobs template
    let session_jobs = args.get_parse("session-jobs", 0u32);
    let priority = args.get_parse("priority", 0u8);
    let timeout = Duration::from_secs_f64(args.get_parse("timeout", 30.0f64));
    let mut stream = std::net::TcpStream::connect(&master)
        .map_err(|e| anyhow::anyhow!("connect {master}: {e}"))?;
    stream.set_read_timeout(Some(timeout))?;
    fleet::wire::write_frame(
        &mut stream,
        &Frame::Submit { name: name.clone(), scheme, session_jobs, priority },
    )?;
    match fleet::wire::read_frame(&mut stream) {
        Ok(Frame::Accepted { job, queue_depth }) => {
            println!("accepted: {name} as job {job} (queue depth {queue_depth})");
            Ok(())
        }
        Ok(Frame::Rejected { reason }) => {
            eprintln!("rejected: {name}: {reason}");
            std::process::exit(1);
        }
        Ok(Frame::Error { code, msg }) => {
            eprintln!("server error {code}: {msg}");
            std::process::exit(1);
        }
        Ok(other) => anyhow::bail!("unexpected reply from {master}: {other:?}"),
        Err(e) => anyhow::bail!("reading verdict from {master}: {e}"),
    }
}

/// One `--report-json` entry per real-gradient job: the loss trajectory
/// and decode counters of a [`GradJobSummary`].
fn grad_json(s: &GradJobSummary) -> sgc::util::json::Json {
    use sgc::util::json::Json;
    let mut o = Json::obj();
    o.set("job", s.job)
        .set("steps", s.steps)
        .set("first_loss", s.first_loss)
        .set("last_loss", s.last_loss)
        .set("audits", s.audits)
        .set("fallback_decodes", s.fallback_decodes)
        .set("losses", Json::Arr(s.losses.iter().map(|&l| Json::from(l)).collect()));
    o
}

/// Export a saved journal (`sgc serve --journal PATH`) as Chrome Trace
/// Event Format JSON — load the output in `chrome://tracing` or
/// Perfetto to see round spans, per-worker service bars and reactor
/// instants on one timeline.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let verb = args.positional.first().map(String::as_str);
    anyhow::ensure!(
        verb == Some("export") && args.has("journal"),
        "usage: sgc trace export --journal PATH [--out PATH]"
    );
    let input = args.get("journal", "");
    let out_path = args.get("out", "target/experiments/trace.json");
    let doc = sgc::util::json::Json::load(&input)?;
    let events = sgc::obs::events_from_json(&doc)?;
    let trace = sgc::obs::chrome_trace(&events);
    trace.save(&out_path)?;
    println!("chrome trace ({} events) → {out_path}", events.len());
    Ok(())
}

/// Run one fleet worker process until the master shuts it down.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let master = args.get("master", "127.0.0.1:7070");
    let id = args.get_parse("id", 0u32);
    let chaos = if args.has_flag("no-chaos") {
        None
    } else {
        Some(ChaosConfig::default_fit(args.get_parse("chaos-seed", 7u64)))
    };
    let mut cfg = WorkerConfig::loopback(id, master.clone(), chaos);
    cfg.base_s = args.get_parse("base-s", cfg.base_s);
    cfg.alpha_s = args.get_parse("alpha-s", cfg.alpha_s);
    println!("worker {id} connecting to {master} …");
    let stats = fleet::run_worker(cfg)?;
    println!(
        "worker {id} done: {} rounds served, {} chaos rounds",
        stats.rounds_served, stats.chaos_rounds
    );
    Ok(())
}

/// Run several schemes × several seeds concurrently on the batch driver
/// and summarise per scheme (`--schemes` takes `+`-separated specs).
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let jobs = args.get_parse("jobs", 480usize);
    let reps = args.get_parse("reps", 4usize).max(1);
    let seed = args.get_parse("seed", 7u64);
    let mu = args.get_parse("mu", 1.0f64);
    let specs = args.get("schemes", "m-sgc:1,2,27+sr-sgc:2,3,23+gc:15+uncoded");
    let schemes: Vec<SchemeConfig> = specs
        .split('+')
        .map(|s| SchemeConfig::parse(n, s.trim()))
        .collect::<anyhow::Result<_>>()?;

    let items: Vec<BatchItem> = schemes
        .iter()
        .flat_map(|scheme| {
            (0..reps).map(move |_| BatchItem {
                scheme: scheme.clone(),
                session: SessionConfig { jobs, mu, ..Default::default() },
            })
        })
        .collect();
    // --record-trace PREFIX dumps every repetition's delay matrix as
    // PREFIX-<scheme>-rep<k>.json (autosaved when the batch driver drops
    // each recording cluster).
    let record = args.options.get("record-trace").cloned();
    let reports = session::run_parallel(items, session::default_threads(), move |i, item| {
        let sim = ge_cluster(item.scheme.n, seed + (i % reps) as u64);
        match &record {
            Some(prefix) => {
                let label: String = item
                    .scheme
                    .label()
                    .chars()
                    .map(|c| if c.is_alphanumeric() { c } else { '_' })
                    .collect();
                let path = format!("{prefix}-{label}-rep{}.json", i % reps);
                Box::new(RecordingCluster::autosave(sim.sync(), path))
                    as Box<dyn Cluster + Send>
            }
            None => Box::new(sim.sync()) as Box<dyn Cluster + Send>,
        }
    })?;

    println!(
        "{:<22} {:>8} {:>3} {:>12} {:>10} {:>9}",
        "scheme", "load", "T", "runtime", "±std", "violations"
    );
    for (k, scheme) in schemes.iter().enumerate() {
        let slice = &reports[k * reps..(k + 1) * reps];
        let runtimes: Vec<f64> = slice.iter().map(|r| r.total_runtime_s).collect();
        let stats = MeanStd::of(&runtimes);
        let violations: usize = slice.iter().map(|r| r.deadline_violations).sum();
        println!(
            "{:<22} {:>8.4} {:>3} {:>11.2}s {:>9.2}s {:>9}",
            scheme.label(),
            scheme.load(),
            scheme.delay(),
            stats.mean,
            stats.std,
            violations
        );
    }
    Ok(())
}

fn cmd_probe(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let t_probe = args.get_parse("t-probe", 80usize);
    let jobs = args.get_parse("jobs", 80usize);
    let seed = args.get_parse("seed", 7u64);
    let mut cluster =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, seed), seed ^ 0xc1);
    let alpha = cluster.latency.alpha_s_per_load;
    let profile = DelayProfile::capture(
        &mut sgc::cluster::SyncAdapter::new(&mut cluster),
        t_probe,
        1.0 / n as f64,
    );
    let space = SearchSpace::paper_default(n);
    for (name, cands) in [
        ("GC", space.gc_candidates()),
        ("SR-SGC", space.sr_sgc_candidates()),
        ("M-SGC", space.m_sgc_candidates()),
    ] {
        let ranked = grid_search(&cands, &profile, alpha, jobs);
        if let Some(best) = ranked.first() {
            println!(
                "{name:<8} best {} load={:.4} est_runtime={:.1}s ({} candidates)",
                best.config.label(),
                best.load,
                best.estimated_runtime_s,
                ranked.len()
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 16usize);
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,4"))?;
    let cfg = TrainConfig {
        models: args.get_parse("models", 4usize),
        iterations: args.get_parse("iters", 25usize),
        batch: args.get_parse("batch", 256usize),
        lr: args.get_parse("lr", 2e-3f32),
        seed: args.get_parse("seed", 7u64),
        ..Default::default()
    };
    let lanes = args.get_parse("lanes", 4usize);
    let pool = std::sync::Arc::new(sgc::runtime::ComputePool::new(
        sgc::runtime::artifacts_dir(),
        lanes,
    )?);
    let dataset = Dataset::generate(DatasetConfig::default());
    let mut trainer = MultiModelTrainer::new(scheme, cfg.clone(), pool, dataset)?;
    let mut cluster = SimCluster::from_gilbert_elliot(
        n,
        GilbertElliot::default_fit(n, cfg.seed),
        cfg.seed ^ 0xc1,
    );
    let report = trainer.run(&mut cluster)?;
    println!(
        "{}: {} jobs in sim {:.1}s (wall {:.1}s), violations={}",
        report.scheme,
        report.jobs_completed,
        report.sim_runtime_s,
        report.wall_runtime_s,
        report.deadline_violations
    );
    for (m, curve) in report.losses.iter().enumerate() {
        if let (Some(first), Some(last)) = (curve.first(), curve.last()) {
            println!(
                "  model {m}: loss {:.4} → {:.4} over {} iterations",
                first.loss, last.loss, last.iteration
            );
        }
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parse("n", 256usize);
    let scheme = SchemeConfig::parse(n, &args.get("scheme", "m-sgc:1,2,27"))?;
    let s = scheme.build(1);
    let spec = s.spec();
    println!("scheme:     {}", spec.name);
    println!("n:          {}", spec.n);
    println!("delay T:    {}", spec.delay);
    println!("load L:     {:.6}", spec.load);
    println!("chunks η:   {}", spec.num_chunks);
    println!("tolerance:  {:?}", spec.tolerance);
    Ok(())
}
