//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that drive this
//! module. It provides warmup, adaptive iteration counts, robust summary
//! statistics, and a stable text + JSON report format so EXPERIMENTS.md can
//! quote the numbers directly.

use crate::util::json::Json;
use crate::util::stats;
use crate::util::timer::fmt_duration;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations contributing to the statistics.
    pub iters: u64,
    /// Mean per-iteration duration.
    pub mean: Duration,
    /// Standard deviation of per-iteration durations.
    pub std: Duration,
    /// Median per-iteration duration.
    pub median: Duration,
    /// 95th-percentile per-iteration duration.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl BenchResult {
    /// Serialize for the `BENCH_*.json` snapshot files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_s", self.mean.as_secs_f64())
            .set("std_s", self.std.as_secs_f64())
            .set("median_s", self.median.as_secs_f64())
            .set("p95_s", self.p95.as_secs_f64())
            .set("min_s", self.min.as_secs_f64())
            .set("max_s", self.max.as_secs_f64());
        o
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10}/iter (median {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.median),
            fmt_duration(self.p95),
            self.iters
        )
    }
}

/// Benchmark session: collects results, prints a table, saves JSON.
pub struct Bench {
    /// Label of the whole bench binary (e.g. "table1").
    pub label: String,
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    /// Hard cap on iterations (expensive end-to-end cases set this to 1-10).
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Bench {
    /// Harness named `label` (honours `SGC_BENCH_FAST=1` for quick runs).
    pub fn new(label: &str) -> Self {
        // Honour SGC_BENCH_FAST=1 for CI-ish quick runs.
        let fast = std::env::var("SGC_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            label: label.to_string(),
            measure_time: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            max_iters: 100_000_000,
            results: Vec::new(),
        }
    }

    /// One-shot style for expensive cases: run `f` exactly `n` times.
    pub fn run_n<F: FnMut()>(&mut self, name: &str, n: u64, mut f: F) -> &BenchResult {
        assert!(n > 0);
        let mut samples = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.push_samples(name, &samples)
    }

    /// Adaptive timing: warm up, then iterate until `measure_time` elapsed.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup_time && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // Measure in batches to amortise clock reads for fast bodies.
        let per_iter = (w0.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-9);
        let batch = ((1e-4 / per_iter) as u64).clamp(1, 10_000);
        let mut samples = Vec::new();
        let m0 = Instant::now();
        let mut total_iters = 0u64;
        while m0.elapsed() < self.measure_time && total_iters < self.max_iters {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        self.push_samples_with_iters(name, &samples, total_iters)
    }

    fn push_samples(&mut self, name: &str, samples: &[f64]) -> &BenchResult {
        let n = samples.len() as u64;
        self.push_samples_with_iters(name, samples, n)
    }

    fn push_samples_with_iters(
        &mut self,
        name: &str,
        samples: &[f64],
        iters: u64,
    ) -> &BenchResult {
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats::mean(samples)),
            std: Duration::from_secs_f64(stats::std_dev(samples)),
            median: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 95.0)),
            min: Duration::from_secs_f64(sorted[0]),
            max: Duration::from_secs_f64(*sorted.last().unwrap()),
        };
        println!("  {r}");
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print header for the bench binary.
    pub fn header(&self) {
        println!("== bench: {} ==", self.label);
    }

    /// Persist all results to `target/experiments/<label>.bench.json`.
    pub fn save(&self) {
        let mut o = Json::obj();
        o.set("label", self.label.as_str());
        o.set("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect()));
        let path = format!("target/experiments/{}.bench.json", self.label);
        if let Err(e) = o.save(&path) {
            crate::log_warn!("could not save {path}: {e}");
        } else {
            println!("  (saved {path})");
        }
    }

    /// Look a recorded result up by case name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Persist a machine-readable perf snapshot to a fixed `path` (e.g.
    /// repo-level `BENCH_3.json`): every recorded case plus
    /// caller-computed headline metrics. Unlike [`Self::save`] the path
    /// is stable across bench labels, so successive PRs overwrite the
    /// same file and the perf trajectory accumulates in version control.
    pub fn save_snapshot(&self, path: &str, metrics: &[(&str, f64)]) {
        let mut o = Json::obj();
        o.set("label", self.label.as_str());
        let mut m = Json::obj();
        for (k, v) in metrics {
            m.set(k, *v);
        }
        o.set("metrics", m);
        o.set("results", Json::Arr(self.results.iter().map(|r| r.to_json()).collect()));
        if let Err(e) = o.save(path) {
            crate::log_warn!("could not save {path}: {e}");
        } else {
            println!("  (saved {path})");
        }
    }

    /// Every case measured so far, in execution order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_n_collects_stats() {
        std::env::set_var("SGC_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let r = b.run_n("sleep-1ms", 5, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(r.mean >= Duration::from_micros(900));
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn adaptive_run_terminates() {
        std::env::set_var("SGC_BENCH_FAST", "1");
        let mut b = Bench::new("selftest2");
        let mut x = 0u64;
        let r = b.run("increment", || {
            x = x.wrapping_add(1);
        });
        assert!(r.iters > 100);
    }
}
