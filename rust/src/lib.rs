//! # sgc — Sequential Gradient Coding for Straggler Mitigation
//!
//! A production-quality reproduction of *"Sequential Gradient Coding For
//! Straggler Mitigation"* (Krishnan, Ebrahimi & Khisti, ICLR 2023).
//!
//! The library is organised as the three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   sans-IO round-protocol engine ([`session::SgcSession`]), the GC /
//!   SR-SGC / M-SGC coding schemes, straggler models, the
//!   serverless-cluster simulator and the parameter-selection probe.
//!   Python is never on this path.
//! * **Layer 2** — `python/compile/model.py`: the JAX forward/backward pass
//!   computing weighted partial gradients per data chunk, AOT-lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — `python/compile/kernels/dense.py`: the Pallas fused dense
//!   kernel the model's hot spot lowers through (interpret=True on CPU).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the `pjrt` feature) and executes them from worker
//! threads.
//!
//! ## Architecture: sans-IO protocol core, event-driven backends
//!
//! The paper's round protocol — assign, observe stragglers via the
//! μ-rule, wait out non-conforming patterns, commit, decode — lives in
//! exactly one place, [`session::SgcSession`], which performs no IO.
//! Execution backends implement the event-driven
//! [`cluster::EventCluster`] API ([`cluster::SimCluster`] with
//! per-worker FIFO contention, recorded-trace replay
//! ([`cluster::RunTrace`]), the live TCP worker fleet
//! ([`fleet::FleetCluster`])) and merely stream per-worker completion
//! events; the multi-tenant [`sched::JobScheduler`] admits any number
//! of sessions onto one shared backend and pumps each session's
//! incremental [`deadline_hint`](session::SgcSession::deadline_hint) /
//! [`try_close_round`](session::SgcSession::try_close_round) μ-rule off
//! the shared event stream. The TCP fleet master is a single-threaded
//! `poll(2)` reactor with an *elastic* worker roster: late joiners are
//! admitted mid-run, dead workers are retired, and the scheduler
//! re-places in-flight sessions onto live spares. An adaptive control
//! plane ([`adapt`]) profiles worker delays from the same event stream,
//! re-fits scheme parameters in the background, and hot-swaps a job's
//! scheme at a job boundary when the re-fit predicts a margin-clearing
//! improvement (`sgc serve --adapt`). Blocking callers
//! ([`session::drive`], trace recording, the probe) bridge through
//! [`cluster::SyncAdapter`]. See `rust/DESIGN.md` (and
//! `rust/docs/OPERATIONS.md` for the operator runbook).
//!
//! ## Quick start
//!
//! Drive a session by hand against the simulated serverless cluster:
//!
//! ```no_run
//! use sgc::cluster::SimCluster;
//! use sgc::coding::SchemeConfig;
//! use sgc::session::{SessionConfig, SessionEvent, SgcSession};
//! use sgc::straggler::GilbertElliot;
//!
//! let scheme = SchemeConfig::msgc(16, /*B=*/1, /*W=*/2, /*lambda=*/4);
//! let mut cluster = SimCluster::from_gilbert_elliot(16, GilbertElliot::default_fit(16, 7), 7);
//! let mut session = SgcSession::new(&scheme, SessionConfig { jobs: 64, ..Default::default() });
//! while !session.is_complete() {
//!     let plan = session.begin_round();                // pull: tasks + per-worker loads
//!     let sample = cluster.sample_round(&plan.loads);  // execute on any backend
//!     session.submit_all(&sample.finish);              // push: completion times
//!     for event in session.close_round() {             // μ-rule, wait-out, commit, decode
//!         if let SessionEvent::JobDecoded { job, at_s } = event {
//!             println!("job {job} decoded at {at_s:.2}s");
//!         }
//!     }
//! }
//! let report = session.into_report();
//! println!("total runtime: {:.2}s", report.total_runtime_s);
//! ```
//!
//! Or use the one-call drivers: [`sched::drive_events`] for a single
//! run on any event backend, [`session::drive`] for the classic
//! blocking path (the [`coordinator::Master`] facade wraps both), and
//! [`session::run_parallel`] for concurrent batches of independent runs
//! (sweeps, repeated seeds) — all return `Result` so a mis-sized
//! cluster fails usably.
//!
//! Multiplex several sessions over **one shared cluster** — the paper's
//! multi-model setting — with real per-worker contention and
//! straggler-aware placement:
//!
//! ```no_run
//! use sgc::cluster::SimCluster;
//! use sgc::coding::SchemeConfig;
//! use sgc::sched::{DisjointPlacement, JobScheduler, JobSpec};
//! use sgc::session::SessionConfig;
//! use sgc::straggler::GilbertElliot;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut sim = SimCluster::from_gilbert_elliot(16, GilbertElliot::default_fit(16, 7), 7);
//! let mut sched = JobScheduler::with_policy(&mut sim, Box::new(DisjointPlacement));
//! for _ in 0..4 {
//!     sched.admit(&JobSpec {
//!         scheme: SchemeConfig::gc(16, 2),
//!         session: SessionConfig { jobs: 24, ..Default::default() },
//!     })?;
//! }
//! let out = sched.run()?;                       // 4 sessions, one fleet
//! for report in &out.reports {
//!     println!("{}: {:.2}s", report.scheme, report.total_runtime_s);
//! }
//! println!("{}", out.utilization);              // makespan, multiplexing gain
//! # Ok(())
//! # }
//! ```
//!
//! (`sgc serve --jobs 4` is the CLI spelling; add `--fleet 8` to run the
//! same multiplexed schedule over live TCP workers.)
//!
//! Run the protocol over a *real* fleet of TCP workers on localhost,
//! with seeded chaos injection and the μ-rule applied to wall-clock
//! arrival times, then replay the recorded trace bit-exactly:
//!
//! ```no_run
//! use sgc::cluster::EventCluster;
//! use sgc::coding::SchemeConfig;
//! use sgc::fleet::{drive_fleet, ChaosConfig, LoopbackFleet};
//! use sgc::session::{self, SessionConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! let scheme = SchemeConfig::gc(8, 2);
//! let cfg = SessionConfig { jobs: 20, ..Default::default() };
//! let mut fleet = LoopbackFleet::spawn(8, Some(ChaosConfig::default_fit(7)))?;
//! let run = drive_fleet(&scheme, &cfg, &mut fleet.cluster)?;  // streaming μ-rule
//! println!("fleet runtime: {:.2}s", run.report.total_runtime_s);
//! let replayed = session::drive(&scheme, &cfg, &mut run.trace.replay().sync())?;
//! assert_eq!(replayed.total_runtime_s, run.report.total_runtime_s);
//! # Ok(())
//! # }
//! ```
//!
//! (`sgc run --fleet 8 --jobs 20` is the CLI spelling of the same run.)

#![warn(missing_docs)]

pub mod adapt;
pub mod bench_harness;
pub mod chaos;
pub mod cluster;
pub mod coding;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod grad;
pub mod obs;
pub mod probe;
pub mod runtime;
pub mod sched;
pub mod session;
pub mod straggler;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result alias (anyhow-based: rich context, no custom enum
/// sprawl; module-level errors that callers match on use `thiserror`-style
/// hand-rolled enums instead).
pub type Result<T> = anyhow::Result<T>;
