//! # sgc — Sequential Gradient Coding for Straggler Mitigation
//!
//! A production-quality reproduction of *"Sequential Gradient Coding For
//! Straggler Mitigation"* (Krishnan, Ebrahimi & Khisti, ICLR 2023).
//!
//! The library is organised as the three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   master round loop, the GC / SR-SGC / M-SGC coding schemes, straggler
//!   models, the serverless-cluster simulator and the parameter-selection
//!   probe. Python is never on this path.
//! * **Layer 2** — `python/compile/model.py`: the JAX forward/backward pass
//!   computing weighted partial gradients per data chunk, AOT-lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — `python/compile/kernels/dense.py`: the Pallas fused dense
//!   kernel the model's hot spot lowers through (interpret=True on CPU).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) and executes them from worker threads.
//!
//! ## Quick start
//!
//! ```no_run
//! use sgc::coding::SchemeConfig;
//! use sgc::coordinator::{Master, RunConfig};
//! use sgc::cluster::SimCluster;
//! use sgc::straggler::GilbertElliot;
//!
//! let scheme = SchemeConfig::msgc(16, /*B=*/1, /*W=*/2, /*lambda=*/4);
//! let mut cluster = SimCluster::from_gilbert_elliot(16, GilbertElliot::default_fit(16, 7), 7);
//! let mut master = Master::new(scheme, RunConfig { jobs: 64, ..Default::default() });
//! let report = master.run(&mut cluster);
//! println!("total runtime: {:.2}s", report.total_runtime_s);
//! ```

pub mod bench_harness;
pub mod cluster;
pub mod experiments;
pub mod coding;
pub mod coordinator;
pub mod probe;
pub mod runtime;
pub mod straggler;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result alias (anyhow-based: rich context, no custom enum
/// sprawl; module-level errors that callers match on use `thiserror`-style
/// hand-rolled enums instead).
pub type Result<T> = anyhow::Result<T>;
