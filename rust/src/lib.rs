//! # sgc — Sequential Gradient Coding for Straggler Mitigation
//!
//! A production-quality reproduction of *"Sequential Gradient Coding For
//! Straggler Mitigation"* (Krishnan, Ebrahimi & Khisti, ICLR 2023).
//!
//! The library is organised as the three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution: the
//!   sans-IO round-protocol engine ([`session::SgcSession`]), the GC /
//!   SR-SGC / M-SGC coding schemes, straggler models, the
//!   serverless-cluster simulator and the parameter-selection probe.
//!   Python is never on this path.
//! * **Layer 2** — `python/compile/model.py`: the JAX forward/backward pass
//!   computing weighted partial gradients per data chunk, AOT-lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — `python/compile/kernels/dense.py`: the Pallas fused dense
//!   kernel the model's hot spot lowers through (interpret=True on CPU).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the `pjrt` feature) and executes them from worker
//! threads.
//!
//! ## Architecture: sans-IO protocol core
//!
//! The paper's round protocol — assign, observe stragglers via the
//! μ-rule, wait out non-conforming patterns, commit, decode — lives in
//! exactly one place, [`session::SgcSession`], which performs no IO.
//! Execution backends (the [`cluster::SimCluster`] simulator, probe
//! trace replays, recorded-trace replay ([`cluster::RunTrace`]), the
//! real-compute PJRT trainer, the parallel batch driver, and the live
//! TCP worker fleet ([`fleet::FleetCluster`])) merely pump it with
//! completion times. Streaming backends use the session's incremental
//! [`deadline_hint`](session::SgcSession::deadline_hint) /
//! [`try_close_round`](session::SgcSession::try_close_round) API to cut
//! stragglers on the wall clock without waiting for all `n` results.
//! See `rust/DESIGN.md`.
//!
//! ## Quick start
//!
//! Drive a session by hand against the simulated serverless cluster:
//!
//! ```no_run
//! use sgc::cluster::SimCluster;
//! use sgc::coding::SchemeConfig;
//! use sgc::session::{SessionConfig, SessionEvent, SgcSession};
//! use sgc::straggler::GilbertElliot;
//!
//! let scheme = SchemeConfig::msgc(16, /*B=*/1, /*W=*/2, /*lambda=*/4);
//! let mut cluster = SimCluster::from_gilbert_elliot(16, GilbertElliot::default_fit(16, 7), 7);
//! let mut session = SgcSession::new(&scheme, SessionConfig { jobs: 64, ..Default::default() });
//! while !session.is_complete() {
//!     let plan = session.begin_round();                // pull: tasks + per-worker loads
//!     let sample = cluster.sample_round(&plan.loads);  // execute on any backend
//!     session.submit_all(&sample.finish);              // push: completion times
//!     for event in session.close_round() {             // μ-rule, wait-out, commit, decode
//!         if let SessionEvent::JobDecoded { job, at_s } = event {
//!             println!("job {job} decoded at {at_s:.2}s");
//!         }
//!     }
//! }
//! let report = session.into_report();
//! println!("total runtime: {:.2}s", report.total_runtime_s);
//! ```
//!
//! Or use the one-call drivers: [`session::drive`] for a single run (the
//! [`coordinator::Master`] facade wraps it), [`session::run_parallel`]
//! for concurrent batches of independent runs (sweeps, repeated seeds) —
//! both return `Result` so a mis-sized cluster fails usably.
//!
//! Run the same protocol over a *real* fleet of TCP workers on
//! localhost, with seeded chaos injection and the μ-rule applied to
//! wall-clock arrival times, then replay the recorded trace bit-exactly:
//!
//! ```no_run
//! use sgc::coding::SchemeConfig;
//! use sgc::fleet::{drive_fleet, ChaosConfig, LoopbackFleet};
//! use sgc::session::{self, SessionConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! let scheme = SchemeConfig::gc(8, 2);
//! let cfg = SessionConfig { jobs: 20, ..Default::default() };
//! let mut fleet = LoopbackFleet::spawn(8, Some(ChaosConfig::default_fit(7)))?;
//! let run = drive_fleet(&scheme, &cfg, &mut fleet.cluster)?;  // streaming μ-rule
//! println!("fleet runtime: {:.2}s", run.report.total_runtime_s);
//! let replayed = session::drive(&scheme, &cfg, &mut run.trace.replay())?;
//! assert_eq!(replayed.total_runtime_s, run.report.total_runtime_s);
//! # Ok(())
//! # }
//! ```
//!
//! (`sgc run --fleet 8 --jobs 20` is the CLI spelling of the same run.)

pub mod bench_harness;
pub mod cluster;
pub mod coding;
pub mod coordinator;
pub mod experiments;
pub mod fleet;
pub mod probe;
pub mod runtime;
pub mod session;
pub mod straggler;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result alias (anyhow-based: rich context, no custom enum
/// sprawl; module-level errors that callers match on use `thiserror`-style
/// hand-rolled enums instead).
pub type Result<T> = anyhow::Result<T>;
