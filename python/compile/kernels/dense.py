"""L1: Pallas fused dense-layer kernels.

The gradient computation's hot spot is the dense matmul in each layer of
the model (fwd and bwd). The kernel is written TPU-style:

* ``(bm, bn, bk)`` tiles sized for VMEM residency (default 128, matching
  the MXU systolic array's 128x128 shape);
* the grid expresses the HBM->VMEM schedule: ``(M/bm, N/bn, K/bk)`` with a
  VMEM accumulator scratch, so each output tile streams K-blocks through
  the MXU without round-tripping HBM;
* a fused bias+activation epilogue kernel avoids a second HBM pass.

On this image Pallas MUST run ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec structure is what we optimize and what
DESIGN.md's TPU-efficiency estimate is based on.

Autodiff: ``pallas_call`` has no JVP rule, so ``dense`` is a
``jax.custom_vjp`` whose forward and backward passes are both built from
the same Pallas matmul kernel (dx = dz @ W^T, dW = x^T @ dz).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-shaped tile. Small problems shrink to the padded size.
DEFAULT_BLOCK = 128


def _block(dim: int, preferred: int) -> int:
    """Pick a block size: the full (padded) dim for small problems, the
    preferred MXU tile otherwise."""
    return min(preferred, max(8, _round_up(dim, 8)))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    """One (bm, bn) output tile; grid axis 2 streams K-blocks."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, y, *, bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK,
           interpret=True):
    """Tiled Pallas matmul ``x @ y`` with zero-padding to block multiples.

    ``x``: (M, K), ``y``: (K, N) -> (M, N) in float32 accumulation.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims: {k} vs {k2}"
    bm = _block(m, bm)
    bn = _block(n, bn)
    bk = _block(k, bk)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def _bias_act_kernel(z_ref, b_ref, o_ref, *, relu: bool):
    z = z_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(z, 0.0) if relu else z


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def bias_act(z, b, *, relu=True, interpret=True):
    """Fused bias-add + optional ReLU epilogue (elementwise, VPU-bound)."""
    m, n = z.shape
    assert b.shape == (n,)
    return pl.pallas_call(
        functools.partial(_bias_act_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((m, n), z.dtype),
        interpret=interpret,
    )(z, jnp.broadcast_to(b, (m, n)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu=True):
    """Fused dense layer ``act(x @ w + b)`` with a Pallas fwd and bwd."""
    return bias_act(matmul(x, w), b, relu=relu)


def _dense_fwd(x, w, b, relu):
    z = bias_act(matmul(x, w), b, relu=False)
    y = jnp.maximum(z, 0.0) if relu else z
    return y, (x, w, z)


def _dense_bwd(relu, res, dy):
    x, w, z = res
    dz = jnp.where(z > 0, dy, 0.0) if relu else dy
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
