"""Pure-jnp correctness oracle for the Pallas kernels and the L2 model.

Everything here is the straightforward jax.numpy implementation — no
Pallas, no custom_vjp — so jax's own autodiff provides the ground-truth
gradients that pytest compares the kernel stack against.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.matmul(x, y)


def dense_ref(x, w, b, relu=True):
    z = x @ w + b
    return jnp.maximum(z, 0.0) if relu else z


def forward_ref(params, x):
    """3-layer MLP forward -> logits."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = dense_ref(x, w1, b1, relu=True)
    h2 = dense_ref(h1, w2, b2, relu=True)
    return dense_ref(h2, w3, b3, relu=False)


def weighted_ce_ref(params, x, y_onehot, wgt):
    """Weighted-sum cross entropy: sum_i w_i * CE_i.

    With w_i = 1/batch for real samples and 0 for padding, partial
    gradients over chunks sum to the full-batch mean gradient.
    """
    logits = forward_ref(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.sum(y_onehot * logp, axis=-1)
    return jnp.sum(wgt * ce)


def grad_program_ref(w1, b1, w2, b2, w3, b3, x, y_onehot, wgt):
    """(loss, grads...) oracle with the same signature as the AOT program."""
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(weighted_ce_ref)(params, x, y_onehot, wgt)
    return (loss,) + tuple(grads)
