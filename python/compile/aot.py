"""AOT: lower the L2 grad program to HLO text for the rust runtime.

HLO *text* (never ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the published xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts/model.hlo.txt \
        [--input 64 --classes 10 --hidden1 128 --hidden2 64 --chunk 64]

Also writes ``model_meta.txt`` next to the HLO with the lowered shapes so
the rust loader can validate its inputs.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_grad_program(input_dim, classes, hidden1, hidden2, chunk):
    shapes = model.make_shapes(input_dim, classes, hidden1, hidden2, chunk)
    args = tuple(shapes["params"]) + (shapes["x"], shapes["y"], shapes["wgt"])
    return jax.jit(model.grad_program).lower(*args)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--input", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--hidden1", type=int, default=128)
    ap.add_argument("--hidden2", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=64)
    ns = ap.parse_args()

    lowered = lower_grad_program(ns.input, ns.classes, ns.hidden1, ns.hidden2, ns.chunk)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(os.path.abspath(ns.out)), exist_ok=True)
    with open(ns.out, "w") as f:
        f.write(text)
    meta_path = os.path.join(os.path.dirname(os.path.abspath(ns.out)), "model_meta.txt")
    with open(meta_path, "w") as f:
        f.write(
            f"input={ns.input}\nclasses={ns.classes}\n"
            f"hidden1={ns.hidden1}\nhidden2={ns.hidden2}\nchunk={ns.chunk}\n"
        )
    print(f"wrote {len(text)} chars to {ns.out} (+ {meta_path})")


if __name__ == "__main__":
    main()
