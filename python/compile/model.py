"""L2: the model's forward/backward as a jax program over one data chunk.

The model is the 3-layer MLP classifier whose dense layers run through the
L1 Pallas kernels (`kernels.dense`). The exported program computes the
*weighted partial gradient* of one padded chunk:

    grad_program(W1, b1, W2, b2, W3, b3, x, y_onehot, wgt)
        -> (loss_sum, gW1, gb1, gW2, gb2, gW3, gb3)

Per-sample weights make chunk gradients additive: with w_i = 1/batch for
real rows and 0 for padding, summing the per-chunk outputs over all chunks
yields exactly the full-batch mean gradient the paper's master decodes.

Python runs only at build time: `aot.py` lowers `grad_program` once to HLO
text; the rust runtime executes it via PJRT on every worker task.
"""

import jax
import jax.numpy as jnp

from .kernels import dense as K


def forward(params, x):
    """MLP forward through the Pallas dense kernels -> logits."""
    w1, b1, w2, b2, w3, b3 = params
    h1 = K.dense(x, w1, b1, True)
    h2 = K.dense(h1, w2, b2, True)
    return K.dense(h2, w3, b3, False)


def weighted_ce(params, x, y_onehot, wgt):
    """Weighted-sum softmax cross entropy (see module docstring)."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.sum(y_onehot * logp, axis=-1)
    return jnp.sum(wgt * ce)


def grad_program(w1, b1, w2, b2, w3, b3, x, y_onehot, wgt):
    """The AOT-exported (loss, grads...) program."""
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(weighted_ce)(params, x, y_onehot, wgt)
    return (loss,) + tuple(grads)


def make_shapes(input_dim=64, classes=10, hidden1=128, hidden2=64, chunk=64):
    """ShapeDtypeStructs for lowering, in program argument order."""
    f32 = jnp.float32
    return dict(
        params=[
            jax.ShapeDtypeStruct((input_dim, hidden1), f32),
            jax.ShapeDtypeStruct((hidden1,), f32),
            jax.ShapeDtypeStruct((hidden1, hidden2), f32),
            jax.ShapeDtypeStruct((hidden2,), f32),
            jax.ShapeDtypeStruct((hidden2, classes), f32),
            jax.ShapeDtypeStruct((classes,), f32),
        ],
        x=jax.ShapeDtypeStruct((chunk, input_dim), f32),
        y=jax.ShapeDtypeStruct((chunk, classes), f32),
        wgt=jax.ShapeDtypeStruct((chunk,), f32),
    )
