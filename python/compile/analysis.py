"""L1/L2 performance analysis (§Perf).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the L1
kernel is optimized structurally: this script reports, for each dense
layer of the model and each matmul in its backward pass,

* the (bm, bn, bk) tile actually selected,
* the VMEM working set per grid step (x-tile + y-tile + acc tile, f32),
* the MXU utilization estimate: fraction of each 128x128 systolic pass
  that carries real data (padding waste),

plus XLA's own cost analysis (flops / bytes) of the whole lowered grad
program — the L2 fusion sanity check.

Usage: python -m compile.analysis [--chunk 64]
"""

import argparse

import jax

from . import aot, model
from .kernels import dense as K

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on a modern TPU
MXU = 128


def tile_report(name, m, k, n):
    bm, bn, bk = K._block(m, K.DEFAULT_BLOCK), K._block(n, K.DEFAULT_BLOCK), K._block(
        k, K.DEFAULT_BLOCK
    )
    mp, kp, np_ = K._round_up(m, bm), K._round_up(k, bk), K._round_up(n, bn)
    vmem = 4 * (bm * bk + bk * bn + 2 * bm * bn)  # x, y, acc + out tiles
    # systolic-array occupancy: real rows/cols vs the padded tile
    util = (m * k * n) / (mp * kp * np_)
    grid = (mp // bm) * (np_ // bn) * (kp // bk)
    print(
        f"  {name:<22} {m:>4}x{k:<4}@{k:>4}x{n:<4} tile=({bm},{bn},{bk}) "
        f"grid={grid:<3} vmem={vmem/1024:>6.1f}KiB ({100*vmem/VMEM_BYTES:.2f}%) "
        f"occupancy={100*util:>5.1f}%"
    )
    return vmem, util


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--input", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--hidden1", type=int, default=128)
    ap.add_argument("--hidden2", type=int, default=64)
    ns = ap.parse_args()
    c, d, h1, h2, cls = ns.chunk, ns.input, ns.hidden1, ns.hidden2, ns.classes

    print("== L1: Pallas dense-kernel tiling (forward) ==")
    worst_vmem = 0
    utils = []
    layers = [("layer1 fwd", c, d, h1), ("layer2 fwd", c, h1, h2), ("layer3 fwd", c, h2, cls)]
    # backward matmuls: dz@W^T and x^T@dz per layer
    bwd = []
    for nm, m, k, n in layers:
        bwd.append((nm.replace("fwd", "bwd dx"), m, n, k))
        bwd.append((nm.replace("fwd", "bwd dW"), k, m, n))
    for nm, m, k, n in layers + bwd:
        vmem, util = tile_report(nm, m, k, n)
        worst_vmem = max(worst_vmem, vmem)
        utils.append(util)
    print(
        f"  worst-case VMEM working set: {worst_vmem/1024:.1f} KiB "
        f"({100*worst_vmem/VMEM_BYTES:.2f}% of 16 MiB) — double-buffering headroom ~{VMEM_BYTES//max(worst_vmem,1)}x"
    )
    print(f"  mean MXU occupancy across matmuls: {100*sum(utils)/len(utils):.1f}%")

    print("\n== L2: XLA cost analysis of the lowered grad program ==")
    lowered = aot.lower_grad_program(d, cls, h1, h2, c)
    compiled = lowered.compile()
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        flops = ca.get("flops", float("nan"))
        bytes_ = ca.get("bytes accessed", float("nan"))
        print(f"  flops/chunk-call: {flops:,.0f}")
        print(f"  bytes accessed:   {bytes_:,.0f}")
        if flops == flops and bytes_ == bytes_:
            print(f"  arithmetic intensity: {flops/bytes_:.2f} flop/byte")
    except Exception as e:  # cost analysis availability varies by backend
        print(f"  (cost analysis unavailable: {e})")
    # fusion sanity: count kernels in the optimized HLO
    try:
        hlo = compiled.as_text()
        fusions = hlo.count(" fusion(")
        print(f"  fused kernels in optimized HLO: {fusions}")
    except Exception:
        pass

    n_params = d * h1 + h1 + h1 * h2 + h2 + h2 * cls + cls
    fwd_flops = 2 * c * (d * h1 + h1 * h2 + h2 * cls)
    print(f"\n  model params: {n_params:,}; fwd flops/chunk: {fwd_flops:,} (bwd ≈ 2x)")


if __name__ == "__main__":
    main()
