"""L2 correctness: the exported grad program vs the pure-jnp oracle, plus
the chunk-additivity property the coding schemes rely on."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_params(rng, input_dim=16, classes=5, h1=12, h2=8):
    f = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.3)
    return (f(input_dim, h1), f(h1), f(h1, h2), f(h2), f(h2, classes), f(classes))


def make_batch(rng, n, input_dim=16, classes=5, weight=None):
    x = jnp.asarray(rng.standard_normal((n, input_dim)).astype(np.float32))
    labels = rng.integers(0, classes, size=n)
    y = jnp.asarray(np.eye(classes, dtype=np.float32)[labels])
    w = jnp.full((n,), 1.0 / n if weight is None else weight, dtype=jnp.float32)
    return x, y, w


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24))
def test_grad_program_matches_oracle(seed, n):
    rng = np.random.default_rng(seed)
    params = make_params(rng)
    x, y, w = make_batch(rng, n)
    got = model.grad_program(*params, x, y, w)
    want = ref.grad_program_ref(*params, x, y, w)
    assert len(got) == len(want) == 7
    for g, e in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-4)


def test_padding_rows_contribute_nothing():
    rng = np.random.default_rng(7)
    params = make_params(rng)
    x, y, w = make_batch(rng, 8)
    # pad with garbage rows at weight 0
    xp = jnp.concatenate([x, jnp.full((4, x.shape[1]), 1e3, jnp.float32)])
    yp = jnp.concatenate([y, jnp.zeros((4, y.shape[1]), jnp.float32)])
    wp = jnp.concatenate([w, jnp.zeros((4,), jnp.float32)])
    a = model.grad_program(*params, x, y, w)
    b = model.grad_program(*params, xp, yp, wp)
    for g, e in zip(a, b):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=1e-4, atol=1e-5)


def test_chunk_gradients_are_additive():
    """sum of per-chunk weighted grads == full-batch grad (the property
    that makes GC's linear decoding correct)."""
    rng = np.random.default_rng(11)
    params = make_params(rng)
    n = 24
    x, y, _ = make_batch(rng, n)
    w_full = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    full = model.grad_program(*params, x, y, w_full)
    # three chunks of 8
    acc = None
    for c in range(3):
        sl = slice(8 * c, 8 * (c + 1))
        out = model.grad_program(*params, x[sl], y[sl], w_full[sl])
        if acc is None:
            acc = list(out)
        else:
            acc = [a + o for a, o in zip(acc, out)]
    for a, e in zip(acc, full):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-4, atol=1e-5)


def test_loss_decreases_under_sgd():
    rng = np.random.default_rng(3)
    params = list(make_params(rng))
    x, y, w = make_batch(rng, 32)
    losses = []
    for _ in range(40):
        out = model.grad_program(*params, x, y, w)
        losses.append(float(out[0]))
        params = [p - 0.2 * g for p, g in zip(params, out[1:])]
    assert losses[-1] < losses[0] * 0.7, losses[::10]
