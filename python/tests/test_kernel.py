"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-block-multiple, the padding path)
and dtypes; assert_allclose against ref.py is the core signal.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as K
from compile.kernels import ref

DIMS = st.integers(min_value=1, max_value=96)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@settings(max_examples=30, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    out = K.matmul(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, y)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 1, 1), (8, 8, 8), (128, 128, 128),
                                   (129, 64, 7), (200, 100, 50), (3, 257, 5)])
def test_matmul_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    np.testing.assert_allclose(np.asarray(K.matmul(x, y)),
                               np.asarray(ref.matmul_ref(x, y)), rtol=1e-5, atol=1e-5)


def test_matmul_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 32)), dtype=jnp.bfloat16)
    y = jnp.asarray(rng.standard_normal((32, 48)), dtype=jnp.bfloat16)
    out = K.matmul(x, y)
    expect = ref.matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(expect), rtol=5e-2, atol=5e-2)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_dense_forward_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    out = K.dense(x, w, b, relu)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.dense_ref(x, w, b, relu)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 40), k=st.integers(2, 40), n=st.integers(2, 40),
       relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_dense_gradients_match_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)

    def f_pallas(x, w, b):
        return jnp.sum(K.dense(x, w, b, relu) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, relu) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-4, atol=1e-4)


def test_dense_relu_mask_exact_zero_region():
    # gradient must be exactly zero where pre-activation < 0
    x = jnp.array([[-10.0, -10.0]])
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, dtype=jnp.float32)
    g = jax.grad(lambda x: jnp.sum(K.dense(x, w, b, True)))(x)
    np.testing.assert_array_equal(np.asarray(g), np.zeros((1, 2), np.float32))


def test_matmul_is_jittable_and_stable_under_vmap_free_use():
    # jit composition over the custom_vjp must not retrace incorrectly
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, 16, 8), rand(rng, 8, 4), rand(rng, 4)
    f = jax.jit(lambda x, w, b: K.dense(x, w, b, True).sum())
    v1 = f(x, w, b)
    v2 = f(x, w, b)
    assert np.allclose(v1, v2)
