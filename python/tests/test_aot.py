"""AOT path: the lowered HLO text must be parseable, numerically faithful
(executed back through xla_client), and stable in its I/O signature."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_hlo_text_structure():
    lowered = aot.lower_grad_program(16, 5, 12, 8, 8)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[16,12]" in text  # W1 shape appears
    assert "f32[8,16]" in text   # x chunk shape appears
    # tuple of 7 results (loss + 6 grads)
    assert "tuple(" in text.replace(") )", "))")


def test_hlo_text_parses_back():
    """The emitted text must parse through XLA's HLO parser — the exact
    entry point the rust loader uses (HloModuleProto::from_text_file).
    Full load-compile-execute numerics are validated on the rust side in
    rust/tests/end_to_end.rs (this jaxlib's python `Client.compile` no
    longer accepts XlaComputation objects)."""
    from jax._src.lib import xla_client as xc

    lowered = aot.lower_grad_program(16, 5, 12, 8, 8)
    text = aot.to_hlo_text(lowered)
    hlo_module = xc._xla.hlo_module_from_text(text)
    back = hlo_module.to_string()
    assert "HloModule" in back
    # parameter/result signature survives the round trip
    assert "f32[16,12]" in back and "f32[8,16]" in back
    # proto ids were re-assigned into 32-bit range (the xla_extension
    # 0.5.1 constraint that forces the text interchange)
    proto = hlo_module.as_serialized_hlo_module_proto()
    assert len(proto) > 1000


def test_lowered_jit_matches_oracle():
    """Numerics of the exact lowered computation (same jit) vs oracle."""
    dims = dict(input_dim=16, classes=5, hidden1=12, hidden2=8, chunk=8)
    rng = np.random.default_rng(5)
    f = lambda *s: rng.standard_normal(s).astype(np.float32) * 0.3
    args = [
        f(16, 12), f(12), f(12, 8), f(8), f(8, 5), f(5),
        f(8, 16),
        np.eye(5, dtype=np.float32)[rng.integers(0, 5, 8)],
        np.full((8,), 1 / 8, np.float32),
    ]
    got = jax.jit(model.grad_program)(*[jnp.asarray(a) for a in args])
    want = ref.grad_program_ref(*[jnp.asarray(a) for a in args])
    assert len(got) == 7
    for g, e in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), rtol=2e-4, atol=2e-4)


def test_cli_writes_artifacts(tmp_path):
    import subprocess, sys, os
    out = tmp_path / "model.hlo.txt"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--input", "16", "--classes", "5", "--hidden1", "12",
         "--hidden2", "8", "--chunk", "8"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.exists() and out.stat().st_size > 1000
    meta = (tmp_path / "model_meta.txt").read_text()
    assert "input=16" in meta and "chunk=8" in meta
